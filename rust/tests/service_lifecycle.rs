//! Service-lifecycle suite: cooperative shutdown at durable phase
//! seals, multi-cohort kill/restart resume, session-flood confinement,
//! and session-deadline degradation (see [`sparsesecagg::service`]).
//!
//! * **Shutdown-at-seal pinning**: a shutdown requested mid-round is
//!   honored only at a durable phase seal (`UploadsClosed` /
//!   `WaveClosed`), with the journal fsynced *before* the typed
//!   [`ShutdownAtSeal`] surfaces — restart resumes the round from the
//!   seal bit-exactly. This pins the fix for shutdown requests being
//!   polled only at round boundaries (and the flush that makes the
//!   interruption durable).
//! * **Kill/resume smoke**: a server hosting two concurrent cohorts is
//!   killed mid-round (seeded crash injection in every cohort's
//!   namespaced journal); a restarted service resumes *every* cohort
//!   from `cohort-<i>/` and finishes all rounds bit-exact against an
//!   uninterrupted reference service.
//! * **Flood confinement**: session-frame budgets are keyed per
//!   (cohort, round) — a flooding client exhausts only its own
//!   cohort's budget for the current round; the same user slot in
//!   another cohort is untouched. Pins the fix for rate-limit budgets
//!   shared across concurrent cohorts. (Per-round replenishment is
//!   unit-tested on `CohortLimiters` itself.)

use sparsesecagg::coordinator::{Coordinator, ShutdownAtSeal};
use sparsesecagg::journal::Journal;
use sparsesecagg::prg::ChaCha20Rng;
use sparsesecagg::protocol::Params;
use sparsesecagg::service::{clear_stop, request_stop, Phase, RoundService,
                            ServiceConfig, SessionClient};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

fn tdir(name: &str) -> PathBuf {
    let p = PathBuf::from(env!("CARGO_TARGET_TMPDIR"))
        .join(format!("service-lifecycle-{name}"));
    let _ = std::fs::remove_dir_all(&p);
    p
}

fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = ChaCha20Rng::from_seed_u64(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.next_f32() - 0.5).collect())
        .collect()
}

/// The service stop flag is process-global; serialize every test that
/// runs a [`RoundService`] so one test's stop cannot park another
/// test's cohorts.
static SERIAL: Mutex<()> = Mutex::new(());
fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(PoisonError::into_inner)
}

// ---------------------------------------------------------------------
// Shutdown-at-seal (coordinator level)
// ---------------------------------------------------------------------

fn always_stop() -> bool {
    true
}

/// A shutdown pending from the start of the round is honored at the
/// *first* durable seal — `UploadsClosed` — with the journal flushed:
/// restart replays the sealed collecting phase and finishes the round
/// bit-exactly.
#[test]
fn shutdown_at_collecting_seal_is_durable_and_resumes_bit_exact() {
    let dir = tdir("seal-collecting");
    let p = Params { n: 8, d: 200, alpha: 0.3, theta: 0.0, c: 1024.0 };
    let ys = grads(p.n, p.d, 0x51de);
    let betas = vec![1.0 / p.n as f64; p.n];

    let mut reference = Coordinator::new_sparse(p, 7);
    let (want, _) = reference.run_round(0, &ys, &betas, &[]).unwrap();

    let mut live = Coordinator::new_sparse(p, 7);
    live.attach_journal(Journal::create(&dir).unwrap()).unwrap();
    live.shutdown_poll = Some(always_stop);
    let err = live.run_round(0, &ys, &betas, &[]).unwrap_err();
    let seal = err
        .downcast_ref::<ShutdownAtSeal>()
        .expect("shutdown must surface as the typed seal interruption");
    assert_eq!(seal.phase, "collecting",
               "first durable seal is the collecting one");
    drop(live); // graceful exit: the journal was flushed at the seal

    let (mut resumed, replay) = Coordinator::from_journal(&dir).unwrap();
    let rp = replay.expect("an interrupted round must replay");
    assert_eq!(rp.round, 0);
    assert!(rp.uploads_closed.is_some(),
            "the UploadsClosed seal must be durable before the \
             shutdown surfaces — this is the flush the fix pins");
    assert!(!rp.completed);
    let (got, ledger) = resumed.resume_round(rp, &ys, &betas, &[]).unwrap();
    assert_eq!(got, want, "resume from the shutdown seal is bit-exact");
    assert_eq!(ledger.resumed_phase, Some("unmasking"));
}

static WAVE_POLLS: AtomicUsize = AtomicUsize::new(0);
/// False at the collecting seal (call 0), true from the first wave
/// seal on — exercises the `WaveClosed` shutdown point.
fn stop_after_collecting() -> bool {
    WAVE_POLLS.fetch_add(1, Ordering::SeqCst) >= 1
}

/// A shutdown arriving during the unmasking phase is honored at the
/// wave seal, *after* `WaveClosed` is durably synced: the restarted
/// round replays the whole wave (no re-solicitation of already-sealed
/// traffic) and finishes bit-exactly.
#[test]
fn shutdown_at_wave_seal_replays_the_sealed_wave_bit_exact() {
    WAVE_POLLS.store(0, Ordering::SeqCst);
    let dir = tdir("seal-wave");
    let p = Params { n: 8, d: 200, alpha: 0.3, theta: 0.0, c: 1024.0 };
    let ys = grads(p.n, p.d, 0x5ea1);
    let betas = vec![1.0 / p.n as f64; p.n];

    let mut reference = Coordinator::new_sparse(p, 21);
    let (want, _) = reference.run_round(0, &ys, &betas, &[]).unwrap();

    let mut live = Coordinator::new_sparse(p, 21);
    live.attach_journal(Journal::create(&dir).unwrap()).unwrap();
    live.shutdown_poll = Some(stop_after_collecting);
    let err = live.run_round(0, &ys, &betas, &[]).unwrap_err();
    let seal = err.downcast_ref::<ShutdownAtSeal>().expect("typed seal");
    assert_eq!(seal.phase, "unmasking");
    drop(live);

    let (mut resumed, replay) = Coordinator::from_journal(&dir).unwrap();
    let rp = replay.expect("replay");
    assert!(rp.uploads_closed.is_some());
    assert_eq!(rp.waves.len(), 1,
               "exactly the one sealed wave must be journaled");
    assert!(!rp.completed);
    let (got, ledger) = resumed.resume_round(rp, &ys, &betas, &[]).unwrap();
    assert_eq!(got, want, "wave-seal resume is bit-exact");
    assert_eq!(ledger.retries, 0);
}

// ---------------------------------------------------------------------
// Service level
// ---------------------------------------------------------------------

fn service_cfg(cohorts: usize, rounds: u32, seed: u64) -> ServiceConfig {
    ServiceConfig {
        cohorts,
        users: 8,
        d: 96,
        alpha: 0.3,
        theta: 0.2,
        rounds,
        seed,
        ..ServiceConfig::default()
    }
}

/// A server hosting two concurrent cohorts dies mid-round (seeded
/// crash in every cohort's namespaced journal); a restarted service
/// resumes every cohort from `<root>/cohort-<i>/` and finishes all
/// rounds bit-exact against an uninterrupted reference service.
#[test]
fn killed_server_resumes_every_cohort_bit_exact() {
    let _g = serial();
    clear_stop();
    let root = tdir("kill-resume");
    let mut base = service_cfg(2, 2, 0xfee1);
    base.journal_root = root.to_string_lossy().into_owned();
    base.crash_plan = "wave-closed:0:torn".into();

    let mut ref_cfg = service_cfg(2, 2, 0xfee1);
    ref_cfg.collect_window_s = 0.0;
    let mut reference = RoundService::start(ref_cfg).unwrap();
    let ref_report = reference.run_to_completion().unwrap();
    assert!(ref_report.failed.is_empty());
    assert_eq!(ref_report.outcomes.len(), 4, "2 cohorts x 2 rounds");

    // The "server": the armed crash kills round 0 in both cohorts.
    let mut svc = RoundService::start(base.clone()).unwrap();
    let report = svc.run_to_completion().unwrap();
    assert_eq!(report.failed.len(), 2,
               "both cohorts must die at the armed journal site");
    for (_, why) in &report.failed {
        assert!(why.contains("injected crash"), "unexpected failure: {why}");
    }
    assert!(report.outcomes.is_empty(), "no round completed pre-crash");
    drop(svc); // the process model dies here

    // Restart: every in-flight cohort resumes from its namespace.
    let mut resume_cfg = base;
    resume_cfg.crash_plan.clear();
    let mut svc2 = RoundService::resume(resume_cfg).unwrap();
    let report2 = svc2.run_to_completion().unwrap();
    assert!(report2.failed.is_empty(),
            "resume must recover cleanly: {:?}", report2.failed);
    assert_eq!(report2.outcomes.len(), 4,
               "every round of every cohort completes after restart");
    for o in &report2.outcomes {
        let want = ref_report
            .outcomes
            .iter()
            .find(|w| w.cohort == o.cohort && w.round == o.round)
            .expect("matching reference round");
        assert_eq!(o.aggregate, want.aggregate,
                   "cohort {} round {} differs after resume",
                   o.cohort, o.round);
        assert_eq!(o.dropped, want.dropped);
        if o.round == 0 {
            assert!(o.resumed,
                    "the interrupted round must replay, not rerun");
        }
    }
}

/// A session flood against one cohort is confined to that cohort's
/// per-round budget: the flooder's own late frames are shed (its
/// `Leave` never lands — it stays joined), while the *same user slot*
/// of the other cohort joins untouched.
#[test]
fn session_flood_is_confined_to_its_cohort() {
    let _g = serial();
    clear_stop();
    let cfg = ServiceConfig {
        cohorts: 2,
        users: 4,
        rounds: 0, // membership only; no rounds
        session_budget: 4,
        ..ServiceConfig::default()
    };
    let mut svc = RoundService::start(cfg).unwrap();
    let addr = svc.local_addr();

    // Cohort 0, user 0 floods: join + 10 heartbeats is 11 frames
    // against a budget of 4, so the trailing Leave must be shed. The
    // garbage frame after it is a drain watermark: per-connection FIFO
    // means once it is counted, everything before it was processed.
    let mut flooder = SessionClient::connect(addr, 0).unwrap();
    flooder.join(0).unwrap();
    for _ in 0..10 {
        flooder.heartbeat().unwrap();
    }
    flooder.leave(0).unwrap();
    flooder.send_raw(&[0xde, 0xad]).unwrap();

    // Cohort 1's user 0 — the same local slot — joins on its own
    // budget.
    let mut peer = SessionClient::connect(addr, 4).unwrap();
    peer.join(1).unwrap();

    assert!(
        svc.tick_until(5000, |s| {
            s.malformed_session_frames() >= 1 && s.member_joined(1, 0)
        }),
        "cohort 1's join must land despite the cohort 0 flood"
    );
    svc.tick().unwrap(); // drain anything queued behind the watermark
    assert!(svc.member_joined(0, 0),
            "the flooder's Leave was past its cohort's budget and must \
             have been shed — before the per-cohort fix the shared \
             budget let cohort 0's flood starve cohort 1 instead");
    assert_eq!(svc.malformed_session_frames(), 1,
               "exactly the one garbage frame is counted");
}

/// A service-level stop lands mid-round at the collecting seal: the
/// cohort parks in `Paused` (not `Failed`), and `resume_cohort`
/// rebuilds it from its namespaced journal and replays the round
/// bit-exactly.
#[test]
fn stop_parks_midround_cohort_and_resume_replays_bit_exact() {
    let _g = serial();
    clear_stop();
    let root = tdir("stop-resume");
    let mut cfg = service_cfg(1, 1, 0x9a5e);
    cfg.journal_root = root.to_string_lossy().into_owned();
    cfg.collect_window_s = 0.05;

    let mut ref_cfg = service_cfg(1, 1, 0x9a5e);
    ref_cfg.collect_window_s = 0.0;
    let mut reference = RoundService::start(ref_cfg).unwrap();
    let ref_report = reference.run_to_completion().unwrap();
    assert_eq!(ref_report.outcomes.len(), 1);

    let mut svc = RoundService::start(cfg).unwrap();
    svc.tick().unwrap();
    assert_eq!(svc.phase(0), Phase::Collecting, "window open");
    request_stop(); // arrives mid-round, before the window closes
    assert!(svc.tick_until(5000, |s| s.phase(0) == Phase::Paused),
            "the stop must park the cohort at the collecting seal");
    assert!(svc.last_error(0).is_none(),
            "a seal-honored stop is a pause, never a failure");

    clear_stop();
    svc.resume_cohort(0).unwrap();
    let report = svc.run_to_completion().unwrap();
    assert!(report.failed.is_empty());
    assert_eq!(report.outcomes.len(), 1);
    assert!(report.outcomes[0].resumed,
            "the interrupted round replays from the seal");
    assert_eq!(report.outcomes[0].aggregate,
               ref_report.outcomes[0].aggregate,
               "pause/resume must be invisible in the aggregate");
}

/// Session members that went silent (aged out) or left by the time
/// the membership window closes degrade to the dropout path — the
/// window always closes, quorum never stalls on a late member.
#[test]
fn stale_and_departed_members_degrade_to_dropouts() {
    let _g = serial();
    clear_stop();
    let cfg = ServiceConfig {
        cohorts: 1,
        users: 8,
        d: 48,
        rounds: 1,
        seed: 11,
        heartbeat_s: 0.02,     // grace = 3 intervals = 60 ms
        collect_window_s: 1.0, // plenty for the joins to land first
        ..ServiceConfig::default()
    };
    let mut svc = RoundService::start(cfg).unwrap();
    let addr = svc.local_addr();
    svc.tick().unwrap(); // open the membership window

    let mut silent = SessionClient::connect(addr, 0).unwrap();
    silent.join(0).unwrap(); // joins, then never heartbeats
    let mut leaver = SessionClient::connect(addr, 1).unwrap();
    leaver.join(0).unwrap();
    leaver.leave(0).unwrap();
    assert!(svc.tick_until(5000, |s| s.member_joined(0, 0)),
            "join must land while the window is open");

    // The window closes on its own wall-clock deadline; by then user 0
    // is 3 heartbeat intervals silent and user 1 has left.
    let report = svc.run_to_completion().unwrap();
    assert!(report.failed.is_empty(), "{:?}", report.failed);
    assert_eq!(report.outcomes.len(), 1);
    assert_eq!(report.outcomes[0].dropped, 2,
               "one aged-out member + one departed member, both on the \
                dropout path; users with no session stay simulated");
}
