//! Property-style integration tests over the protocol (no artifacts
//! needed): random cohort sizes, compression ratios, dropout sets —
//! exact mask cancellation and metric invariants must hold for all.

use sparsesecagg::coordinator::{Coordinator, GroupedCoordinator};
use sparsesecagg::field;
use sparsesecagg::metrics;
use sparsesecagg::network::draw_dropouts;
use sparsesecagg::prg::ChaCha20Rng;
use sparsesecagg::protocol::group::GroupLayout;
use sparsesecagg::protocol::messages::UnmaskResponse;
use sparsesecagg::protocol::{secagg, sparse, Params};
use sparsesecagg::quantize;
use sparsesecagg::testutil::{prop_shrink, shrink_groups};

fn random_grads(rng: &mut ChaCha20Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..d).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
        .collect()
}

/// Protocol output must EXACTLY equal the unmasked recomputation for
/// random (n, α, θ, dropout) draws — the core soundness property.
#[test]
fn sparse_aggregation_exact_over_random_configs() {
    for case in 0..12u64 {
        let mut rng = ChaCha20Rng::from_seed_u64(7_000 + case);
        let n = 4 + (rng.next_u32() as usize % 12);
        let d = 200 + (rng.next_u32() as usize % 1200);
        let alpha = 0.05 + 0.6 * rng.next_f32() as f64;
        let theta = 0.3 * rng.next_f32() as f64;
        let params = Params { n, d, alpha, theta, c: 2048.0 };
        let (users, mut server) = sparse::setup(params, 100 + case);
        let ys = random_grads(&mut rng, n, d);
        let beta = 1.0 / n as f64;

        // random dropout set below threshold
        let max_drop = n - (n / 2 + 1);
        let n_drop = (rng.next_u32() as usize) % (max_drop + 1);
        let dropped: Vec<usize> = (0..n_drop).collect();

        server.begin_round();
        let mut scratch = vec![0u32; d];
        for u in users.iter().filter(|u| !dropped.contains(&u.id)) {
            let plan = u.mask_plan(case as u32, &params, &mut scratch);
            server.receive_upload(
                u.masked_upload(case as u32, &ys[u.id], beta, &params, plan));
        }
        let req = server.unmask_request();
        let responses: Vec<UnmaskResponse> = users
            .iter()
            .filter(|u| !dropped.contains(&u.id))
            .map(|u| u.respond_unmask(&req))
            .collect();
        server.finish_round(case as u32, &responses).unwrap();

        // unmasked recomputation (rounding stream via the public seekable
        // accessor, zero masks, same quantizer)
        let mut want = vec![0u32; d];
        for u in users.iter().filter(|u| !dropped.contains(&u.id)) {
            let plan = u.mask_plan(case as u32, &params, &mut scratch);
            let rands = u.rounding_uniforms(case as u32, plan.indices.len());
            for (&l, &r) in plan.indices.iter().zip(&rands) {
                let v = quantize::quantize_mask_one(
                    ys[u.id][l as usize], r, 0, true, params.scale(beta),
                    params.c);
                want[l as usize] = field::add(want[l as usize], v);
            }
        }
        assert_eq!(server.aggregate_field(), &want[..],
                   "case {case}: n={n} d={d} alpha={alpha:.2} drop={n_drop}");
    }
}

/// Quorum math: with θ < 0.5 and quorum enforcement the round always
/// completes; metrics see dropped users as None.
#[test]
fn rounds_complete_under_heavy_dropout() {
    let params = Params { n: 14, d: 800, alpha: 0.25, theta: 0.45,
                          c: 1024.0 };
    let mut coord = Coordinator::new_sparse(params, 11);
    let betas = vec![1.0 / 14.0; 14];
    let mut rng = ChaCha20Rng::from_seed_u64(5);
    let ys = random_grads(&mut rng, 14, 800);
    for round in 0..6 {
        let dropped = draw_dropouts(14, 0.45, round, 9, true);
        let (agg, ledger) =
            coord.run_round(round, &ys, &betas, &dropped).unwrap();
        assert_eq!(agg.len(), 800);
        let uploads = coord.sparse_upload_indices().unwrap();
        for &i in &dropped {
            assert!(uploads[i].is_none());
            assert_eq!(
                ledger.up_bytes[i], 0,
                "dropped user {i} should upload nothing in round {round}");
        }
    }
}

/// Privacy trend (Thm 2 / Fig 4a): measured T grows with α and tracks
/// the closed form within Monte-Carlo slack.
#[test]
fn privacy_t_tracks_theory() {
    let n = 60;
    let d = 30_000;
    let gamma = 1.0 / 3.0;
    let theta = 0.0;
    let mut last_t = 0.0;
    for &alpha in &[0.05, 0.15, 0.3] {
        let params = Params { n, d, alpha, theta, c: 1024.0 };
        let mut coord = Coordinator::new_sparse(params, 21);
        let betas = vec![1.0 / n as f64; n];
        let ys: Vec<Vec<f32>> = vec![vec![0.01; d]; n];
        coord.run_round(0, &ys, &betas, &[]).unwrap();
        let honest = coord.honest_mask(gamma);
        let sample = metrics::privacy_histogram(
            d, coord.sparse_upload_indices().unwrap(), &honest);
        let t_meas = sample.mean_t();
        let t_theory = metrics::theoretical_t(alpha, theta, gamma, n);
        assert!(t_meas > last_t, "T not increasing in alpha");
        // mean-T conditioned on coverage is ≥ the unconditional theory
        // value; allow generous band.
        assert!(t_meas > 0.6 * t_theory && t_meas < 3.0 * t_theory + 2.0,
                "alpha={alpha}: T={t_meas} theory={t_theory}");
        last_t = t_meas;
    }
}

/// The private mask's purpose (paper §III-B, citing Bonawitz): if a user
/// is *delayed* rather than dropped — its upload surfaces only after the
/// server already reconstructed its pairwise seeds and stripped its
/// pairwise masks — the leftover private mask r_i keeps the late upload
/// indistinguishable from uniform, so nothing about y_i leaks.
#[test]
fn delayed_user_upload_stays_masked_by_private_seed() {
    let params = Params { n: 8, d: 4_000, alpha: 0.4, theta: 0.1,
                          c: 1024.0 };
    let (users, mut server) = sparse::setup(params, 55);
    let mut rng = ChaCha20Rng::from_seed_u64(66);
    let ys = random_grads(&mut rng, 8, 4_000);
    let beta = 1.0 / 8.0;
    let delayed = 3usize;

    // Round runs without user 3 (server treats it as dropped and
    // reconstructs its DH secret to remove its pairwise masks).
    server.begin_round();
    let mut scratch = vec![0u32; params.d];
    for u in users.iter().filter(|u| u.id != delayed) {
        let plan = u.mask_plan(0, &params, &mut scratch);
        server.receive_upload(u.masked_upload(0, &ys[u.id], beta, &params,
                                              plan));
    }
    let req = server.unmask_request();
    let responses: Vec<UnmaskResponse> = users
        .iter()
        .filter(|u| u.id != delayed)
        .map(|u| u.respond_unmask(&req))
        .collect();
    server.finish_round(0, &responses).unwrap();

    // The delayed upload arrives late. The server knows all of user 3's
    // pairwise seeds by now (it reconstructed the DH secret during
    // Unmask) — simulate the strongest curious server by subtracting
    // every pairwise mask from the late upload. The residual is
    // φ(ȳ_3) + r_3 and must still look uniform over the field: the
    // private seed of a NON-survivor is never requested, so r_3 stands.
    let plan = users[delayed].mask_plan(0, &params, &mut scratch);
    let up = users[delayed].masked_upload(0, &ys[delayed], beta, &params,
                                          plan);
    let mut residual = up.values.clone();
    for j in 0..params.n {
        if j == delayed {
            continue;
        }
        let (add_seed, mult_seed) = users[delayed].pair_seeds(j);
        let support = sparsesecagg::masking::pairwise_support(
            mult_seed, 0, params.rho(), params.d);
        let values = sparsesecagg::masking::mask_values(
            add_seed, sparsesecagg::masking::STREAM_ADDITIVE, 0,
            support.len());
        // subtract user 3's signed contribution at the matching
        // positions of its upload
        for (&l, &r) in support.iter().zip(&values) {
            let k = up.indices.binary_search(&l).unwrap();
            residual[k] = if sparsesecagg::masking::pair_sign(delayed, j) {
                field::sub(residual[k], r)
            } else {
                field::add(residual[k], r)
            };
        }
    }
    // Statistical checks: residual ~ uniform ⇒ mean ≈ q/2 and almost no
    // "small" values; a bare quantized gradient (what would leak without
    // r_3) clusters entirely within ±c·|scale·y| of 0 mod q.
    let mean = residual.iter().map(|&v| v as f64).sum::<f64>()
        / residual.len() as f64;
    let half = field::Q as f64 / 2.0;
    assert!((mean - half).abs() < half * 0.1,
            "late upload no longer uniform: mean={mean:.3e}");
    let small = residual.iter()
        .filter(|&&v| v < 1_000_000 || v > field::Q - 1_000_000)
        .count() as f64 / residual.len() as f64;
    assert!(small < 0.01, "quantized structure visible: {small}");
}

/// Wire-codec fuzz: random mutations of valid frames must decode to an
/// error or a valid message — never panic (index bounds, allocation
/// bombs, etc.).
#[test]
fn wire_codec_survives_fuzzing() {
    use sparsesecagg::protocol::messages::SparseMaskedUpload;
    use sparsesecagg::protocol::wire;
    let mut rng = ChaCha20Rng::from_seed_u64(0xf022);
    let base = SparseMaskedUpload {
        id: 3,
        indices: vec![1, 5, 77, 901],
        values: vec![10, 20, 30, 40],
        d: 1000,
    };
    let clean = wire::encode_sparse_upload(&base);
    assert_eq!(wire::decode_sparse_upload(&clean).unwrap().values,
               base.values);
    for _ in 0..3000 {
        let mut buf = clean.clone();
        // 1–4 random byte mutations
        for _ in 0..1 + rng.next_u32() % 4 {
            let i = rng.next_u32() as usize % buf.len();
            buf[i] ^= (rng.next_u32() % 255 + 1) as u8;
        }
        // also random truncation sometimes
        if rng.next_u32() % 4 == 0 {
            buf.truncate(rng.next_u32() as usize % (buf.len() + 1));
        }
        // must not panic:
        let _ = wire::decode_sparse_upload(&buf);
        let _ = wire::decode_dense_upload(&buf);
        let _ = wire::decode_unmask_response(&buf);
        let _ = wire::peek_header(&buf);
    }
}

/// Random split of the users who sit a round out into the two failure
/// phases the protocol distinguishes: `phase1` never upload (true
/// dropouts — their DH secrets get reconstructed), `phase2` upload but
/// never answer the unmask request (delayed users — their private seeds
/// get reconstructed from others' shares). Exactly `n - phase1 - phase2`
/// responders remain.
fn storm_split(rng: &mut ChaCha20Rng, n: usize, responders: usize)
               -> (Vec<usize>, Vec<usize>, Vec<usize>) {
    let total_out = n - responders;
    let phase1 = rng.next_u32() as usize % (total_out + 1);
    let mut ids: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = (rng.next_u32() as usize) % (i + 1);
        ids.swap(i, j);
    }
    let p1 = ids[..phase1].to_vec();
    let p2 = ids[phase1..total_out].to_vec();
    let resp = ids[total_out..].to_vec();
    (p1, p2, resp)
}

/// One dropout-storm scenario, fully determined by its fields — the
/// explicit-case shape `testutil::prop_shrink` needs: on failure the
/// driver halves the cohort / drops users / halves the dimension and
/// reports the smallest still-failing reproduction.
#[derive(Clone, Copy, Debug)]
struct StormCase {
    n: usize,
    d: usize,
    alpha: f64,
    seed: u64,
}

fn gen_storm(rng: &mut ChaCha20Rng) -> StormCase {
    StormCase {
        n: 5 + (rng.next_u32() as usize % 8),
        d: 150 + (rng.next_u32() as usize % 400),
        alpha: 0.2 + 0.5 * rng.next_f32() as f64,
        seed: rng.next_u64(),
    }
}

fn shrink_storm(c: &StormCase) -> Vec<StormCase> {
    let mut out = Vec::new();
    if c.n > 5 {
        out.push(StormCase { n: (c.n / 2).max(5), ..*c }); // halve cohort
        out.push(StormCase { n: c.n - 1, ..*c }); // drop one user
    }
    if c.d > 80 {
        out.push(StormCase { d: c.d / 2, ..*c });
    }
    out
}

/// Dropout storm, SparseSecAgg: random per-phase dropout patterns down to
/// exactly ⌊N/2⌋+1 responders must recover the round — bit-exactly — and
/// one responder fewer must fail cleanly with an error (never garbage).
#[test]
fn dropout_storm_at_threshold_sparse() {
    prop_shrink(15, gen_storm, shrink_storm, |c: &StormCase| {
        let StormCase { n, d, alpha, seed } = *c;
        let rng = &mut ChaCha20Rng::from_seed_u64(seed);
        let params = Params { n, d, alpha, theta: 0.3, c: 1024.0 };
        let (users, mut server) =
            sparse::setup(params, 3_000 + rng.next_u32() as u64);
        let quorum = n / 2 + 1; // = t + 1
        let (p1, _p2, responders) = storm_split(rng, n, quorum);
        let ys = random_grads(rng, n, d);
        let beta = 1.0 / n as f64;

        // --- at threshold: recovery succeeds and is exact.
        server.begin_round();
        let mut scratch = vec![0u32; d];
        for u in users.iter().filter(|u| !p1.contains(&u.id)) {
            let plan = u.mask_plan(0, &params, &mut scratch);
            server.receive_upload(
                u.masked_upload(0, &ys[u.id], beta, &params, plan));
        }
        let req = server.unmask_request();
        let responses: Vec<UnmaskResponse> = users
            .iter()
            .filter(|u| responders.contains(&u.id))
            .map(|u| u.respond_unmask(&req))
            .collect();
        assert_eq!(responses.len(), quorum);
        server.finish_round(0, &responses).unwrap_or_else(|e| {
            panic!("threshold recovery failed (n={n}, |p1|={}, \
                    responders={quorum}): {e:#}", p1.len())
        });
        // Exactness: every uploader (responding or delayed) contributes.
        let mut want = vec![0u32; d];
        for u in users.iter().filter(|u| !p1.contains(&u.id)) {
            let plan = u.mask_plan(0, &params, &mut scratch);
            let rands = u.rounding_uniforms(0, plan.indices.len());
            for (&l, &r) in plan.indices.iter().zip(&rands) {
                let v = quantize::quantize_mask_one(
                    ys[u.id][l as usize], r, 0, true, params.scale(beta),
                    params.c);
                want[l as usize] = field::add(want[l as usize], v);
            }
        }
        assert_eq!(server.aggregate_field(), &want[..]);

        // --- one responder below threshold: clean failure.
        server.begin_round();
        for u in users.iter().filter(|u| !p1.contains(&u.id)) {
            let plan = u.mask_plan(1, &params, &mut scratch);
            server.receive_upload(
                u.masked_upload(1, &ys[u.id], beta, &params, plan));
        }
        let req = server.unmask_request();
        let starved: Vec<UnmaskResponse> = users
            .iter()
            .filter(|u| responders[1..].contains(&u.id))
            .map(|u| u.respond_unmask(&req))
            .collect();
        assert_eq!(starved.len(), quorum - 1);
        assert!(server.finish_round(1, &starved).is_err(),
                "recovery below threshold must fail (n={n})");
    });
}

/// Dropout storm, SecAgg baseline: same phase machinery, same threshold
/// boundary. (The private trainer state needed for a bit-exact
/// recomputation is deliberately not exposed by `secagg::User`, so
/// success is checked through the dequantized weighted sum, which the
/// exact mask cancellation makes deterministic within quantization
/// error.)
#[test]
fn dropout_storm_at_threshold_secagg() {
    prop_shrink(15, gen_storm, shrink_storm, |c: &StormCase| {
        let StormCase { n, d, seed, .. } = *c;
        let rng = &mut ChaCha20Rng::from_seed_u64(seed ^ 0x5ec);
        let params = Params { n, d, alpha: 1.0, theta: 0.3, c: 65536.0 };
        let (users, mut server) =
            secagg::setup(params, 7_000 + rng.next_u32() as u64);
        let quorum = n / 2 + 1;
        let (p1, _p2, responders) = storm_split(rng, n, quorum);
        let ys = random_grads(rng, n, d);
        let beta = 1.0 / n as f64;

        server.begin_round();
        for u in users.iter().filter(|u| !p1.contains(&u.id)) {
            server.receive_upload(
                u.masked_upload(0, &ys[u.id], beta, &params));
        }
        let req = server.unmask_request();
        let responses: Vec<UnmaskResponse> = users
            .iter()
            .filter(|u| responders.contains(&u.id))
            .map(|u| u.respond_unmask(&req))
            .collect();
        assert_eq!(responses.len(), quorum);
        let out = server.finish_round(0, &responses).unwrap_or_else(|e| {
            panic!("threshold recovery failed (n={n}): {e:#}")
        });
        // Masks cancelled ⇒ dequantized ≈ Σ_uploaders scale·β·y within
        // one quantization step per uploader.
        let scale = 1.0 / (1.0 - params.theta);
        for l in (0..d).step_by(17) {
            let uploaders =
                users.iter().filter(|u| !p1.contains(&u.id));
            let want: f64 = uploaders
                .map(|u| beta * scale * ys[u.id][l] as f64)
                .sum();
            assert!((out[l] as f64 - want).abs()
                        < n as f64 / params.c as f64 + 1e-4,
                    "l={l} got={} want={want}", out[l]);
        }

        // One fewer responder: must fail, not return garbage.
        server.begin_round();
        for u in users.iter().filter(|u| !p1.contains(&u.id)) {
            server.receive_upload(
                u.masked_upload(1, &ys[u.id], beta, &params));
        }
        let req = server.unmask_request();
        let starved: Vec<UnmaskResponse> = users
            .iter()
            .filter(|u| responders[1..].contains(&u.id))
            .map(|u| u.respond_unmask(&req))
            .collect();
        assert!(server.finish_round(1, &starved).is_err());
    });
}

/// One grouped dropout-storm scenario: a roster of `groups` even
/// groups, with the `target` group squeezed down to its own recovery
/// threshold. Fully determined by its fields; on failure the shrinker
/// walks the group dimension too ([`shrink_groups`]: merge to one flat
/// group, halve the group count) alongside the model dimension.
#[derive(Clone, Copy, Debug)]
struct GroupedStormCase {
    n: usize,
    groups: usize,
    d: usize,
    alpha: f64,
    target: usize,
    seed: u64,
}

fn gen_grouped_storm(rng: &mut ChaCha20Rng) -> GroupedStormCase {
    let groups = 2 + (rng.next_u32() as usize % 3); // 2..=4
    let per = 3 + (rng.next_u32() as usize % 4); // 3..=6 users/group
    GroupedStormCase {
        n: groups * per,
        groups,
        d: 120 + (rng.next_u32() as usize % 300),
        alpha: 0.3 + 0.4 * rng.next_f32() as f64,
        target: rng.next_u32() as usize % groups,
        seed: rng.next_u64(),
    }
}

fn shrink_grouped_storm(c: &GroupedStormCase) -> Vec<GroupedStormCase> {
    let mut out: Vec<GroupedStormCase> = shrink_groups(c.groups)
        .into_iter()
        .map(|g| GroupedStormCase {
            groups: g,
            target: c.target.min(g - 1),
            ..*c
        })
        .collect();
    if c.d > 80 {
        out.push(GroupedStormCase { d: c.d / 2, ..*c });
    }
    out
}

/// Grouped dropout storm: any single group squeezed to exactly
/// t(n_g)+1 responders still recovers its round (the grouped round
/// completes with no failed group), and one responder fewer fails
/// *only that group's subtree* — the rest of the tree aggregates and
/// the failure is reported, confined, never garbage. When the shrinker
/// merges everything into one flat group, below-threshold becomes a
/// whole-round error (there is no other subtree to survive), which is
/// exactly the flat contract.
#[test]
fn grouped_dropout_storm_confines_threshold_failures() {
    prop_shrink(10, gen_grouped_storm, shrink_grouped_storm,
                |c: &GroupedStormCase| {
        let GroupedStormCase { n, groups, d, alpha, target, seed } = *c;
        let params = Params { n, d, alpha, theta: 0.3, c: 1024.0 };
        let layout = GroupLayout::groups(n, groups);
        let g = target.min(layout.count() - 1);
        let (start, n_g) = (layout.start(g), layout.len(g));
        let quorum = n_g / 2 + 1; // t(n_g) + 1
        let betas = vec![1.0 / n as f64; n];
        let rng = &mut ChaCha20Rng::from_seed_u64(seed);
        let ys = random_grads(rng, n, d);

        // --- at threshold: exactly t+1 responders in the target group.
        let dropped: Vec<usize> =
            (start..start + (n_g - quorum)).collect();
        let mut coord = GroupedCoordinator::new_sparse(
            params, seed ^ 0x9001, GroupLayout::groups(n, groups));
        let out = coord
            .run_round(0, &ys, &betas, &dropped)
            .unwrap_or_else(|e| {
                panic!("threshold grouped recovery failed (n={n}, \
                        groups={groups}, target={g}, n_g={n_g}): {e:#}")
            });
        assert!(out.failed.is_empty(),
                "group at t+1 responders must recover: {:?}", out.failed);
        assert_eq!(out.aggregate.len(), d);

        // --- one fewer responder: only the target subtree fails.
        let starved: Vec<usize> =
            (start..start + (n_g - quorum + 1)).collect();
        let mut coord = GroupedCoordinator::new_sparse(
            params, seed ^ 0x9001, GroupLayout::groups(n, groups));
        if layout.count() == 1 {
            assert!(coord.run_round(0, &ys, &betas, &starved).is_err(),
                    "flat round below threshold must fail");
        } else {
            let out = coord
                .run_round(0, &ys, &betas, &starved)
                .unwrap_or_else(|e| {
                    panic!("confined failure escalated to a whole-round \
                            error (n={n}, groups={groups}): {e:#}")
                });
            assert_eq!(out.failed.len(), 1,
                       "exactly the target group fails: {:?}", out.failed);
            assert_eq!(out.failed[0].0, g);
            assert_eq!(out.aggregate.len(), d);
        }
    });
}

/// Compression (Thm 1): measured upload fraction ≈ p ≤ α.
#[test]
fn compression_ratio_matches_theorem_1() {
    let n = 40;
    let d = 60_000;
    for &alpha in &[0.05, 0.1, 0.3] {
        let params = Params { n, d, alpha, theta: 0.0, c: 1024.0 };
        let (users, _server) = sparse::setup(params, 77);
        let mut scratch = vec![0u32; d];
        let plan = users[7].mask_plan(0, &params, &mut scratch);
        let frac = plan.indices.len() as f64 / d as f64;
        assert!(frac <= alpha * 1.05 + 0.003,
                "alpha={alpha}: frac={frac} violates Thm 1");
        assert!(frac >= params.p() * 0.9,
                "alpha={alpha}: frac={frac} below p={}", params.p());
    }
}
