//! Crash-restart differential suite — the durability half of the
//! secure-aggregation story ([`sparsesecagg::journal`]).
//!
//! * **Crash matrix**: ≥ 8 seeded crash points — per-phase append
//!   boundaries (`before`/`torn`/`after` at every durable record kind)
//!   — × both protocols × all three unmask executors. For every cell
//!   the crashed-and-resumed round's aggregate, per-user byte ledger,
//!   and simulated clock are bit-exactly those of the uninterrupted
//!   reference, and so is every subsequent round.
//! * **Mid-recovery crash**: the crash fires inside the
//!   equivocator-exclusion recovery loop (solicitation of the retry
//!   wave, either side of the durable `Excluded` record) under a
//!   byzantine injector + two-faced value-poisoner; resume still
//!   excludes exactly the equivocator and lands on the reference
//!   aggregate.
//! * **Netsim composition**: crash and resume both run over the seeded
//!   network-impairment simulator (latency + reordering jitter); the
//!   resumed round is pinned against the ideal-bus reference, proving
//!   replay is delivery-order independent.
//! * **Torn-tail property**: for *any* truncation point of the journal
//!   file, restart either fails with a clean typed error or resumes
//!   bit-exactly — never a corrupted aggregate.
//! * **Crash-churn soak**: ≥ 20 rounds with seeded per-round crash
//!   points (including snapshot-compaction crashes), dropout churn,
//!   and netsim jitter: zero recoverable rounds lost, every round
//!   bit-exact, the whole trajectory deterministic under the seed.

use sparsesecagg::adversary::{Adversary, TwoFaced};
use sparsesecagg::coordinator::{Coordinator, ProtocolKind};
use sparsesecagg::exec::ExecMode;
use sparsesecagg::journal::{CrashPlan, Journal, JournalError};
use sparsesecagg::netsim::{LinkProfile, NetSim, NetSimConfig};
use sparsesecagg::network::RoundLedger;
use sparsesecagg::prg::ChaCha20Rng;
use sparsesecagg::protocol::Params;
use sparsesecagg::transport::Transport;
use std::path::PathBuf;

fn params(n: usize, d: usize, alpha: f64) -> Params {
    Params { n, d, alpha, theta: 0.0, c: 1024.0 }
}

fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = ChaCha20Rng::from_seed_u64(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.next_f32() - 0.5).collect())
        .collect()
}

/// Fresh per-test journal directory under the cargo tmp root.
fn tdir(name: &str) -> PathBuf {
    let p = PathBuf::from(env!("CARGO_TARGET_TMPDIR"))
        .join(format!("crash-recovery-{name}"));
    let _ = std::fs::remove_dir_all(&p);
    p
}

fn build(kind: ProtocolKind, p: Params, entropy: u64,
         mode: ExecMode) -> Coordinator {
    let mut c = match kind {
        ProtocolKind::Sparse => Coordinator::new_sparse(p, entropy),
        ProtocolKind::SecAgg => Coordinator::new_secagg(p, entropy),
    };
    tune(&mut c, mode);
    c
}

/// The knobs a restarted process re-applies from its config (they are
/// operator state, not journaled state).
fn tune(c: &mut Coordinator, mode: ExecMode) {
    c.threads = 3;
    c.shard_size = 64;
    c.exec_mode = mode;
}

/// The bit-exactness contract: aggregate, per-user byte ledgers, the
/// simulated communication clock, and the recovery accounting. Compute
/// wall-times, scheduling stats, and journal/replay meta-counters are
/// process-local and excluded by construction.
fn assert_ledger_eq(got: &RoundLedger, want: &RoundLedger, ctx: &str) {
    assert_eq!(got.up_bytes, want.up_bytes, "{ctx}: up_bytes");
    assert_eq!(got.down_bytes, want.down_bytes, "{ctx}: down_bytes");
    assert_eq!(got.comm_time_s.to_bits(), want.comm_time_s.to_bits(),
               "{ctx}: comm clock not bit-exact \
                ({} vs {})", got.comm_time_s, want.comm_time_s);
    assert_eq!(got.excluded_users, want.excluded_users,
               "{ctx}: excluded_users");
    assert_eq!(got.retries, want.retries, "{ctx}: retries");
    assert_eq!(got.phases.len(), want.phases.len(), "{ctx}: phase count");
    for (g, w) in got.phases.iter().zip(&want.phases) {
        assert_eq!(g.name, w.name, "{ctx}: phase order");
        assert_eq!(g.up_bytes, w.up_bytes, "{ctx}: phase {} up", g.name);
        assert_eq!(g.down_bytes, w.down_bytes,
                   "{ctx}: phase {} down", g.name);
        assert_eq!(g.comm_time_s.to_bits(), w.comm_time_s.to_bits(),
                   "{ctx}: phase {} clock", g.name);
    }
}

fn assert_round_eq(got: &(Vec<f32>, RoundLedger),
                   want: &(Vec<f32>, RoundLedger), ctx: &str) {
    assert_eq!(got.0, want.0, "{ctx}: aggregate diverged");
    assert_ledger_eq(&got.1, &want.1, ctx);
}

fn assert_crashed(err: &anyhow::Error, ctx: &str) {
    assert!(
        matches!(err.downcast_ref::<JournalError>(),
                 Some(JournalError::Crashed)),
        "{ctx}: expected the typed injected-crash error, got {err:#}");
}

// ---------------------------------------------------------------------
// Crash matrix: every append-boundary site × both protocols × all
// three executors.
// ---------------------------------------------------------------------

/// Per-phase and append-boundary crash points for an honest 3-round
/// run, armed in round 1: `before` (record lost), `torn` (partial
/// frame — the restart must truncate it away), and `after` (record
/// durable, ack lost) at every record kind the round writes.
const SITES: [&str; 11] = [
    "round-start:0:before",
    "upload:1:torn",
    "upload:2:after",
    "uploads-closed:0:before",
    "uploads-closed:0:after",
    "wave-solicited:0:after",
    "response:1:torn",
    "wave-closed:0:before",
    "wave-closed:0:after",
    "round-complete:0:before",
    "round-complete:0:after",
];

/// Run the full crash catalog for one (protocol, executor) cell:
/// 3-round runs with rotating dropouts, the crash armed in round 1,
/// restart via [`Coordinator::from_journal`], and every round from the
/// resumed one onward pinned bit-exact against the uninterrupted
/// reference.
fn crash_matrix(kind: ProtocolKind, mode: ExecMode, tag: &str) {
    let p = params(8, 120, 0.4);
    let entropy = 0x3c11;
    let ys = grads(p.n, p.d, 0xd1ff ^ entropy);
    let betas = vec![1.0 / p.n as f64; p.n];
    let drops: [&[usize]; 3] = [&[], &[3], &[5, 6]];

    let mut refc = build(kind, p, entropy, mode);
    let reference: Vec<(Vec<f32>, RoundLedger)> = (0..3u32)
        .map(|r| refc.run_round(r, &ys, &betas, drops[r as usize]).unwrap())
        .collect();

    for plan in SITES {
        let ctx = format!("{tag}/{plan}");
        let dir = tdir(&format!("matrix-{tag}-{}", plan.replace(':', "-")));
        let mut live = build(kind, p, entropy, mode);
        live.attach_journal(Journal::create(&dir).unwrap()).unwrap();
        // Round 0 completes durably; journaling must not perturb it.
        let r0 = live.run_round(0, &ys, &betas, drops[0]).unwrap();
        assert!(r0.1.journal_bytes > 0, "{ctx}: journal must be written");
        assert_round_eq(&r0, &reference[0], &format!("{ctx} (round 0)"));

        live.journal_mut()
            .unwrap()
            .set_crash_plan(CrashPlan::parse(plan).unwrap());
        let err = live.run_round(1, &ys, &betas, drops[1]).unwrap_err();
        assert_crashed(&err, &ctx);
        drop(live); // the process model dies here

        let (mut resumed, replay) = Coordinator::from_journal(&dir)
            .unwrap_or_else(|e| panic!("{ctx}: restart failed: {e:#}"));
        tune(&mut resumed, mode);
        let next = match replay {
            Some(rp) if rp.round == 1 => {
                let was_complete = rp.completed;
                let got = resumed
                    .resume_round(rp, &ys, &betas, drops[1])
                    .unwrap_or_else(|e| {
                        panic!("{ctx}: resume failed: {e:#}")
                    });
                assert!(got.1.resumed_phase.is_some(), "{ctx}");
                if was_complete {
                    // `round-complete:0:after`: the completion record
                    // survived, only the ack was lost — resume merely
                    // recomputes the durably finished round.
                    assert_eq!(got.1.resumed_phase, Some("complete"),
                               "{ctx}");
                }
                assert_round_eq(&got, &reference[1],
                                &format!("{ctx} (resumed round 1)"));
                2u32
            }
            Some(rp) => {
                // `round-start:0:before`: round 1 never reached the
                // file; the journal holds completed round 0, which
                // resume recomputes bit-exactly before moving on.
                assert_eq!((rp.round, rp.completed), (0, true), "{ctx}");
                let got = resumed
                    .resume_round(rp, &ys, &betas, drops[0])
                    .unwrap();
                assert_eq!(got.1.resumed_phase, Some("complete"), "{ctx}");
                assert_round_eq(&got, &reference[0],
                                &format!("{ctx} (recomputed round 0)"));
                1u32
            }
            None => panic!("{ctx}: journal lost the setup anchor"),
        };
        // The round the crash orphaned (if resume recovered an earlier
        // one) and everything after run live on the restarted process.
        for r in next..3 {
            let got = resumed
                .run_round(r, &ys, &betas, drops[r as usize])
                .unwrap();
            assert_round_eq(&got, &reference[r as usize],
                            &format!("{ctx} (round {r})"));
        }
    }
}

#[test]
fn crash_matrix_sparse_stealing() {
    crash_matrix(ProtocolKind::Sparse, ExecMode::Stealing,
                 "sparse-stealing");
}

#[test]
fn crash_matrix_sparse_windowed() {
    crash_matrix(ProtocolKind::Sparse, ExecMode::Windowed,
                 "sparse-windowed");
}

#[test]
fn crash_matrix_sparse_monolithic() {
    crash_matrix(ProtocolKind::Sparse, ExecMode::Monolithic,
                 "sparse-monolithic");
}

#[test]
fn crash_matrix_secagg_stealing() {
    crash_matrix(ProtocolKind::SecAgg, ExecMode::Stealing,
                 "secagg-stealing");
}

#[test]
fn crash_matrix_secagg_windowed() {
    crash_matrix(ProtocolKind::SecAgg, ExecMode::Windowed,
                 "secagg-windowed");
}

#[test]
fn crash_matrix_secagg_monolithic() {
    crash_matrix(ProtocolKind::SecAgg, ExecMode::Monolithic,
                 "secagg-monolithic");
}

// ---------------------------------------------------------------------
// Mid-recovery crashes under byzantine pressure.
// ---------------------------------------------------------------------

/// Crash inside the equivocator-exclusion recovery loop: byzantine ids
/// {0, 1} (0 a silenced catalog injector, 1 a two-faced value-poisoner
/// whose detection happens in reconstruction — deterministic on
/// replay). The armed sites bracket the recovery wave: the retry
/// solicitation record, and either side of the durable `Excluded`
/// record. Resume runs with no adversary process attached (it died
/// with the coordinator); the journaled validated frames carry the
/// poisoned responses, so the restart re-identifies and excludes the
/// same equivocator and lands on the reference aggregate.
///
/// The injector's own endpoint (user 0) is the one legitimate billing
/// divergence: its rejected garbage is billed live but never journaled,
/// and without the adversary its silencing lapses for the model
/// broadcast — so user 0's byte rows are excluded from the comparison.
/// Everything clock-carrying (sealed wave size snapshots) replays
/// exactly, so the simulated clock is still bit-exact.
fn recovery_crash_cell(plan: &str) {
    let p = params(10, 150, 0.35);
    let entropy = 0xa11ce;
    let ys = grads(p.n, p.d, 0xbad ^ entropy);
    let betas = vec![1.0 / p.n as f64; p.n];
    let mk_adv = || {
        let mut a = Adversary::new(0.2, entropy ^ 0xad);
        a.two_faced = vec![(1, TwoFaced::PoisonValues)];
        a
    };

    let mut refc = build(ProtocolKind::Sparse, p, entropy,
                         ExecMode::Stealing);
    let mut adv = mk_adv();
    let (want_agg, want_ledger) = refc
        .run_round_adversarial(0, &ys, &betas, &[], &mut adv)
        .unwrap();
    assert_eq!(want_ledger.excluded_users, vec![1]);
    assert_eq!(want_ledger.retries, 1);

    let dir = tdir(&format!("recovery-{}", plan.replace(':', "-")));
    let mut live = build(ProtocolKind::Sparse, p, entropy,
                         ExecMode::Stealing);
    live.attach_journal(Journal::create(&dir).unwrap()).unwrap();
    live.journal_mut()
        .unwrap()
        .set_crash_plan(CrashPlan::parse(plan).unwrap());
    let mut adv = mk_adv();
    let err = live
        .run_round_adversarial(0, &ys, &betas, &[], &mut adv)
        .unwrap_err();
    assert_crashed(&err, plan);
    drop(live);

    let (mut resumed, replay) = Coordinator::from_journal(&dir).unwrap();
    tune(&mut resumed, ExecMode::Stealing);
    let rp = replay.unwrap_or_else(|| panic!("{plan}: no replay"));
    assert_eq!(rp.round, 0, "{plan}");
    let (got_agg, got_ledger) =
        resumed.resume_round(rp, &ys, &betas, &[]).unwrap_or_else(|e| {
            panic!("{plan}: recovery round lost across the crash: {e:#}")
        });
    assert_eq!(got_agg, want_agg, "{plan}: aggregate diverged");
    assert_eq!(got_ledger.excluded_users, vec![1], "{plan}");
    assert_eq!(got_ledger.retries, 1, "{plan}");
    assert_eq!(got_ledger.resumed_phase, Some("unmasking"), "{plan}");
    assert!(got_ledger.replayed_frames > 0, "{plan}");
    assert_eq!(got_ledger.up_bytes[1..], want_ledger.up_bytes[1..],
               "{plan}: honest up_bytes");
    assert_eq!(got_ledger.down_bytes[1..], want_ledger.down_bytes[1..],
               "{plan}: honest down_bytes");
    assert_eq!(got_ledger.comm_time_s.to_bits(),
               want_ledger.comm_time_s.to_bits(),
               "{plan}: comm clock not bit-exact");
}

#[test]
fn crash_before_durable_exclusion_reidentifies_the_equivocator() {
    recovery_crash_cell("excluded:0:before");
}

#[test]
fn crash_after_durable_exclusion_replays_it() {
    recovery_crash_cell("excluded:0:after");
}

#[test]
fn crash_soliciting_the_retry_wave_redoes_it() {
    recovery_crash_cell("wave-solicited:1:after");
}

// ---------------------------------------------------------------------
// Netsim composition.
// ---------------------------------------------------------------------

/// Crash and restart both behind the seeded impairment simulator
/// (latency + jitter at 2× latency ⇒ reordering every phase, loss-free
/// so the round is recoverable by construction). The resumed process
/// gets a *fresh* netsim with a different seed — its delivery order
/// shares nothing with the crashed attempt — yet the round is pinned
/// bit-exact against the ideal-bus reference: replay and the protocol
/// itself are delivery-order independent.
#[test]
fn crash_resume_composes_with_netsim_reordering() {
    let p = params(9, 140, 0.35);
    let entropy = 0x7e15;
    let ys = grads(p.n, p.d, 0x31u64 ^ entropy);
    let betas = vec![1.0 / p.n as f64; p.n];
    let wan = LinkProfile {
        latency_s: 1e-3,
        jitter_s: 2e-3,
        bandwidth_bps: 50e6,
        loss: 0.0,
        die_after: None,
    };

    let mut refc = build(ProtocolKind::Sparse, p, entropy,
                         ExecMode::Stealing);
    let reference: Vec<(Vec<f32>, RoundLedger)> = (0..2u32)
        .map(|r| refc.run_round(r, &ys, &betas, &[]).unwrap())
        .collect();

    let dir = tdir("netsim");
    let bus = Box::new(NetSim::over_bus(
        p.n, NetSimConfig::uniform(entropy ^ 0x9e7, wan)));
    let mut live = Coordinator::new_sparse_on(p, entropy, bus);
    tune(&mut live, ExecMode::Stealing);
    live.attach_journal(Journal::create(&dir).unwrap()).unwrap();
    live.journal_mut()
        .unwrap()
        .set_crash_plan(CrashPlan::parse("wave-closed:0:torn").unwrap());
    let err = live.run_round(0, &ys, &betas, &[]).unwrap_err();
    assert_crashed(&err, "netsim cell");
    drop(live);

    let (mut resumed, replay) = Coordinator::from_journal_on(&dir, |n| {
        Box::new(NetSim::over_bus(
            n, NetSimConfig::uniform(entropy ^ 0x515, wan)))
    })
    .unwrap();
    tune(&mut resumed, ExecMode::Stealing);
    let rp = replay.expect("in-flight round journaled");
    let got = resumed.resume_round(rp, &ys, &betas, &[]).unwrap();
    assert_eq!(got.1.resumed_phase, Some("unmasking"));
    assert_round_eq(&got, &reference[0], "netsim resumed round");
    let got1 = resumed.run_round(1, &ys, &betas, &[]).unwrap();
    assert_round_eq(&got1, &reference[1], "netsim follow-on round");
    assert!(resumed.bus_clock_s() > 0.0,
            "the impairment layer must have cost simulated time");
}

// ---------------------------------------------------------------------
// Torn-tail truncation property.
// ---------------------------------------------------------------------

/// For ANY truncation point of the journal file — mid-record, at a
/// record boundary, inside the setup prefix, even byte 0 — restart
/// either fails with a clean *typed* error or resumes to bit-exact
/// equality with the reference. Never a panic, never a silently wrong
/// aggregate.
#[test]
fn every_truncation_point_fails_cleanly_or_resumes_bit_exactly() {
    let p = params(6, 80, 0.5);
    let entropy = 0x70a4;
    let ys = grads(p.n, p.d, 0x7e44 ^ entropy);
    let betas = vec![1.0 / p.n as f64; p.n];

    let mut refc = build(ProtocolKind::Sparse, p, entropy,
                         ExecMode::Stealing);
    let reference: Vec<(Vec<f32>, RoundLedger)> = (0..2u32)
        .map(|r| refc.run_round(r, &ys, &betas, &[]).unwrap())
        .collect();

    let dir = tdir("torn-source");
    let mut live = build(ProtocolKind::Sparse, p, entropy,
                         ExecMode::Stealing);
    live.attach_journal(Journal::create(&dir).unwrap()).unwrap();
    for r in 0..2u32 {
        live.run_round(r, &ys, &betas, &[]).unwrap();
    }
    drop(live);
    let full = std::fs::read(dir.join("round.journal")).unwrap();
    assert!(full.len() > 64);

    let mut rng = ChaCha20Rng::from_seed_u64(0x7064);
    let cuts: Vec<usize> = std::iter::once(0)
        .chain(std::iter::once(full.len()))
        .chain((0..46).map(|_| rng.next_u32() as usize % full.len()))
        .collect();
    for (i, &cut) in cuts.iter().enumerate() {
        let d2 = tdir(&format!("torn-cut-{i}"));
        std::fs::create_dir_all(&d2).unwrap();
        std::fs::write(d2.join("round.journal"), &full[..cut]).unwrap();
        match Coordinator::from_journal(&d2) {
            Err(e) => {
                // Pre-setup truncation: the typed grammar error, not a
                // panic and not a half-built cohort.
                assert!(e.downcast_ref::<JournalError>().is_some(),
                        "cut {cut}: untyped restart error: {e:#}");
            }
            Ok((mut resumed, replay)) => {
                tune(&mut resumed, ExecMode::Stealing);
                let next = match replay {
                    Some(rp) => {
                        let r = rp.round;
                        let got = resumed
                            .resume_round(rp, &ys, &betas, &[])
                            .unwrap_or_else(|e| {
                                panic!("cut {cut}: resume failed: {e:#}")
                            });
                        assert_round_eq(
                            &got, &reference[r as usize],
                            &format!("cut {cut} (resumed round {r})"));
                        r + 1
                    }
                    // Truncated back to the bare setup anchor: nothing
                    // in flight, rounds simply rerun.
                    None => 0,
                };
                for r in next..2 {
                    let got =
                        resumed.run_round(r, &ys, &betas, &[]).unwrap();
                    assert_round_eq(&got, &reference[r as usize],
                                    &format!("cut {cut} (round {r})"));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Crash-churn soak.
// ---------------------------------------------------------------------

/// The soak's per-round crash catalog: sites that occur in every
/// honest round. Compaction sites are armed separately on snapshot
/// boundaries.
const SOAK_SITES: [&str; 10] = [
    "upload:0:torn",
    "upload:1:after",
    "uploads-closed:0:before",
    "uploads-closed:0:after",
    "wave-solicited:0:after",
    "response:0:torn",
    "wave-closed:0:before",
    "wave-closed:0:after",
    "round-complete:0:before",
    "round-complete:0:after",
];

const COMPACTION_SITES: [&str; 3] =
    ["compaction:0:before", "compaction:0:torn", "compaction:0:after"];

/// One crash-churn soak run: 22 rounds over jittery reordering links
/// with snapshot compaction every 3 rounds, seeded dropout churn
/// (0..=2 leavers), and a seeded coin that crashes ~60% of rounds at a
/// seeded site (compaction crashes on snapshot boundaries). Every
/// crash restarts via [`Coordinator::from_journal_on`] on a fresh
/// netsim; every round — resumed or not — is pinned bit-exact against
/// the uninterrupted ideal-bus reference. Returns the per-round
/// aggregates for the determinism comparison.
fn crash_churn_soak_run(entropy: u64) -> Vec<Vec<f32>> {
    const ROUNDS: u32 = 22;
    const SNAP: u32 = 3;
    let p = params(10, 130, 0.35);
    let ys = grads(p.n, p.d, 0x50a4 ^ entropy);
    let betas = vec![1.0 / p.n as f64; p.n];
    let wan = LinkProfile {
        latency_s: 1e-3,
        jitter_s: 2e-3,
        bandwidth_bps: 50e6,
        loss: 0.0,
        die_after: None,
    };
    let mk_bus = |n: usize, seed: u64| -> Box<dyn Transport> {
        Box::new(NetSim::over_bus(n, NetSimConfig::uniform(seed, wan)))
    };

    let mut refc = build(ProtocolKind::Sparse, p, entropy,
                         ExecMode::Stealing);
    let reference: Vec<(Vec<f32>, RoundLedger)> = (0..ROUNDS)
        .map(|r| {
            refc.run_round(r, &ys, &betas, &churn(entropy, r)).unwrap()
        })
        .collect();

    let dir = tdir(&format!("soak-{entropy}"));
    let mut coord = Coordinator::new_sparse_on(
        p, entropy, mk_bus(p.n, entropy ^ 0x9e70));
    tune(&mut coord, ExecMode::Stealing);
    let mut j = Journal::create(&dir).unwrap();
    j.snapshot_every = SNAP;
    coord.attach_journal(j).unwrap();

    let mut crash_rng = ChaCha20Rng::from_seed_u64(entropy ^ 0xc2a5);
    let mut crashes = 0usize;
    let mut compaction_crashes = 0usize;
    let mut aggs = Vec::new();
    for r in 0..ROUNDS {
        let dropped = churn(entropy, r);
        let on_snap = (r + 1) % SNAP == 0;
        let crash_here = crash_rng.next_u32() % 10 < 6;
        let plan = if crash_here {
            let site = if on_snap && crash_rng.next_u32() % 2 == 0 {
                compaction_crashes += 1;
                COMPACTION_SITES
                    [crash_rng.next_u32() as usize % COMPACTION_SITES.len()]
            } else {
                SOAK_SITES
                    [crash_rng.next_u32() as usize % SOAK_SITES.len()]
            };
            Some(CrashPlan::parse(site).unwrap())
        } else {
            None
        };

        let got = if let Some(plan) = plan {
            crashes += 1;
            coord.journal_mut().unwrap().set_crash_plan(plan);
            let err = coord
                .run_round(r, &ys, &betas, &dropped)
                .expect_err("armed crash plan must fire this round");
            assert_crashed(&err, &format!("soak round {r}"));
            // restart: fresh process model, fresh impaired network.
            let (c2, replay) = Coordinator::from_journal_on(&dir, |n| {
                mk_bus(n, entropy ^ 0x9e70 ^ (r as u64 + 1) * 0x517c)
            })
            .unwrap_or_else(|e| {
                panic!("soak round {r}: restart failed: {e:#}")
            });
            coord = c2;
            tune(&mut coord, ExecMode::Stealing);
            coord.journal_mut().unwrap().snapshot_every = SNAP;
            match replay {
                // The common shape: the crashed round itself is in the
                // journal (possibly already completed) — resume it.
                Some(rp) if rp.round == r => coord
                    .resume_round(rp, &ys, &betas, &dropped)
                    .unwrap_or_else(|e| {
                        panic!("soak round {r}: lost a recoverable \
                                round: {e:#}")
                    }),
                // Post-compaction-commit crash: the log is already the
                // snapshot prefix, nothing in flight — recompute live.
                _ => coord.run_round(r, &ys, &betas, &dropped).unwrap(),
            }
        } else {
            coord.run_round(r, &ys, &betas, &dropped).unwrap()
        };
        assert_round_eq(&got, &reference[r as usize],
                        &format!("soak round {r}"));
        aggs.push(got.0);
    }
    assert!(crashes >= 8,
            "soak seed too gentle: only {crashes} crashes fired");
    assert!(compaction_crashes >= 1,
            "soak must exercise a compaction crash");
    aggs
}

/// Seeded dropout churn for soak round `r`: 0..=2 distinct leavers.
fn churn(entropy: u64, r: u32) -> Vec<usize> {
    let mut rng =
        ChaCha20Rng::from_seed_u64(entropy ^ 0xc42 ^ (r as u64) << 17);
    let k = rng.next_u32() as usize % 3;
    let mut pool: Vec<usize> = (0..10).collect();
    let mut leave = Vec::new();
    for _ in 0..k {
        let i = rng.next_u32() as usize % pool.len();
        leave.push(pool.swap_remove(i));
    }
    leave.sort_unstable();
    leave
}

/// ≥ 20 rounds of seeded crash churn (including compaction crashes)
/// over reordering links: zero recoverable rounds lost, every round
/// bit-exact to its reference, and the full trajectory deterministic
/// under the seed.
#[test]
fn crash_churn_soak_loses_nothing_and_is_deterministic() {
    let a = crash_churn_soak_run(0x5eed);
    let b = crash_churn_soak_run(0x5eed);
    assert_eq!(a.len(), 22);
    for (r, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x, y, "soak round {r} not deterministic under seed");
    }
}
