//! Adversarial robustness suite: full rounds under hostile traffic.
//!
//! A seeded byzantine catalog (replays, spoofed senders, wrong
//! dimensions, bitmap/values mismatches, hostile counts, garbage
//! payloads, unknown tags, truncations, phase confusion, replayed
//! responses, forged shares) is driven through the frame-level round
//! driver for **both protocols and all three unmask executors**. The
//! contract under attack:
//!
//! * every detectable injection is rejected with a typed error and
//!   counted — never a panic;
//! * a surviving round is **bit-exactly** equal to the honest reference
//!   (the same round with the byzantine users simply dropped) — no
//!   silent aggregate corruption;
//! * an unsurvivable round (byzantine pressure breaks quorum, or a
//!   two-faced survivor poisons share values behind valid geometry)
//!   fails with a clean `Err`;
//! * **recovery catalog** (post-PR 5): every attack that previously
//!   could only *cleanly abort* — two-faced share-value poisoning,
//!   equivocation-by-geometry — now completes **bit-exactly** equal to
//!   the honest-reference-minus-excluded-users aggregate, across both
//!   protocols and all three unmask executors, with the round ledger's
//!   `excluded_users` / `retries` asserted exactly.

use sparsesecagg::adversary::{Adversary, Attack, TwoFaced, FULL_CATALOG};
use sparsesecagg::coordinator::Coordinator;
use sparsesecagg::exec::{ExecMode, Executor};
use sparsesecagg::field;
use sparsesecagg::prg::ChaCha20Rng;
use sparsesecagg::protocol::messages::UnmaskResponse;
use sparsesecagg::protocol::shard::ShardConfig;
use sparsesecagg::protocol::{secagg, sparse, Params};

fn params(n: usize, d: usize, alpha: f64, theta: f64) -> Params {
    Params { n, d, alpha, theta, c: 1024.0 }
}

fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = ChaCha20Rng::from_seed_u64(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.next_f32() - 0.5).collect())
        .collect()
}

/// (mode, shard_size): shard_size 0 selects the monolithic path.
const EXECUTORS: &[(ExecMode, usize)] = &[
    (ExecMode::Stealing, 64),
    (ExecMode::Windowed, 64),
    (ExecMode::Monolithic, 0),
];

fn coordinator(secagg_proto: bool, p: Params, entropy: u64,
               mode: ExecMode, shard: usize) -> Coordinator {
    let mut c = if secagg_proto {
        Coordinator::new_secagg(p, entropy)
    } else {
        Coordinator::new_sparse(p, entropy)
    };
    c.exec_mode = mode;
    c.shard_size = shard;
    c.threads = 3;
    c
}

/// One attacked round vs its honest reference: byzantine users 0 and 1
/// inject `attack` frames; the reference round simply drops them. The
/// attacked round must complete bit-exact and count every injection as
/// rejected.
fn assert_attack_is_shed(secagg_proto: bool, attack: Attack,
                         mode: ExecMode, shard: usize) {
    let alpha = if secagg_proto { 1.0 } else { 0.3 };
    let p = params(10, 500, alpha, 0.0);
    let ys = grads(p.n, p.d, 0xfeed);
    let betas = vec![1.0 / p.n as f64; p.n];
    let dropped = vec![7usize];
    let frac = 0.2; // byzantine ids 0, 1

    let mut reference = coordinator(secagg_proto, p, 77, mode, shard);
    let mut ref_dropped = dropped.clone();
    ref_dropped.extend([0usize, 1]);
    let (want, _) =
        reference.run_round(3, &ys, &betas, &ref_dropped).unwrap();

    let mut attacked = coordinator(secagg_proto, p, 77, mode, shard);
    let mut adv = Adversary::with_catalog(frac, 0xa77ac4, &[attack]);
    let (got, ledger) = attacked
        .run_round_adversarial(3, &ys, &betas, &dropped, &mut adv)
        .unwrap_or_else(|e| {
            panic!("{attack:?}/{mode:?} should be survivable: {e:#}")
        });

    assert!(adv.injected > 0, "{attack:?} injected nothing");
    assert_eq!(ledger.rejected_frames, adv.injected,
               "{attack:?}/{mode:?}: every injected frame must be \
                rejected, none silently accepted");
    assert_eq!(got, want,
               "{attack:?}/{mode:?} secagg={secagg_proto}: attacked \
                aggregate differs from honest reference");
}

#[test]
fn catalog_rounds_are_bit_exact_for_sparse_all_executors() {
    for &(mode, shard) in EXECUTORS {
        for &attack in FULL_CATALOG {
            assert_attack_is_shed(false, attack, mode, shard);
        }
    }
}

#[test]
fn catalog_rounds_are_bit_exact_for_secagg_all_executors() {
    for &(mode, shard) in EXECUTORS {
        for &attack in FULL_CATALOG {
            assert_attack_is_shed(true, attack, mode, shard);
        }
    }
}

/// The whole catalog at once, several rounds on one coordinator: the
/// bus and the ingest state machine must come back clean every round.
#[test]
fn full_catalog_storm_across_rounds() {
    let p = params(10, 400, 0.35, 0.0);
    let ys = grads(p.n, p.d, 0xcafe);
    let betas = vec![1.0 / p.n as f64; p.n];
    let mut reference = coordinator(false, p, 31, ExecMode::Stealing, 64);
    let mut attacked = coordinator(false, p, 31, ExecMode::Stealing, 64);
    let mut adv = Adversary::new(0.2, 9);
    for round in 0..4 {
        let (want, _) = reference
            .run_round(round, &ys, &betas, &[0, 1])
            .unwrap();
        let (got, ledger) = attacked
            .run_round_adversarial(round, &ys, &betas, &[], &mut adv)
            .unwrap();
        assert_eq!(got, want, "round {round}");
        assert!(ledger.rejected_frames > 0);
    }
}

/// Enough byzantine users to break quorum: the round must fail with a
/// clean error (reconstruction refuses below threshold), never panic
/// and never emit a fabricated aggregate.
#[test]
fn byzantine_pressure_breaking_quorum_fails_cleanly() {
    let p = params(10, 300, 0.4, 0.0);
    let ys = grads(p.n, p.d, 0xdead);
    let betas = vec![1.0 / p.n as f64; p.n];
    // 4 byzantine + 2 dropped => 4 survivors < t+1 = 6.
    let dropped = vec![7usize, 8];
    for &(mode, shard) in EXECUTORS {
        let mut attacked = coordinator(false, p, 13, mode, shard);
        let mut adv = Adversary::new(0.4, 5);
        let res = attacked
            .run_round_adversarial(0, &ys, &betas, &dropped, &mut adv);
        assert!(res.is_err(), "{mode:?}: quorum loss must be an error");
    }
}

/// A *two-faced* survivor: uploads honestly, then returns shares with
/// valid geometry (right x, right owners) but poisoned words. Ingest
/// cannot tell — but reconstruction cross-checks every extra share
/// against the interpolated polynomial, so the round fails cleanly
/// instead of silently folding garbage into the unmasking. All three
/// executors consume the same reconstruction, so all three must refuse.
#[test]
fn two_faced_share_poisoning_fails_cleanly_not_silently() {
    let p = params(8, 300, 0.4, 0.0);
    let ys = grads(p.n, p.d, 0xbeef);
    let beta = 1.0 / p.n as f64;
    for &(mode, shard) in EXECUTORS {
        let (users, mut server) = sparse::setup(p, 5);
        server.begin_round();
        let mut scratch = vec![0u32; p.d];
        for u in &users {
            let plan = u.mask_plan(0, &p, &mut scratch);
            server.receive_upload(
                u.masked_upload(0, &ys[u.id], beta, &p, plan));
        }
        server.close_uploads();
        let req = server.unmask_request();
        let mut responses: Vec<UnmaskResponse> =
            users.iter().map(|u| u.respond_unmask(&req)).collect();
        // User 0 equivocates on every seed share it holds.
        for (_, s) in responses[0].seed_shares.iter_mut() {
            s.y[0] = field::add(s.y[0], 1);
        }
        for r in responses {
            server.try_receive_response(r).unwrap(); // shape-valid
        }
        let responses = server.take_responses();
        let res = match (mode, shard) {
            (ExecMode::Stealing, s) if s > 0 => {
                let exec = Executor::new(2);
                server
                    .finish_round_stealing(0, &responses,
                                           &ShardConfig::new(s, 2), &exec)
                    .map(|_| ())
            }
            (ExecMode::Windowed, s) if s > 0 => server
                .finish_round_sharded(0, &responses,
                                      &ShardConfig::new(s, 2))
                .map(|_| ()),
            _ => server.finish_round(0, &responses).map(|_| ()),
        };
        assert!(res.is_err(),
                "{mode:?}: poisoned shares must fail the round cleanly");
    }
}

/// Same two-faced poisoning against the SecAgg baseline server.
#[test]
fn two_faced_share_poisoning_fails_cleanly_secagg() {
    let p = params(8, 250, 1.0, 0.0);
    let ys = grads(p.n, p.d, 0xabad);
    let beta = 1.0 / p.n as f64;
    let (users, mut server) = secagg::setup(p, 6);
    server.begin_round();
    for u in &users {
        server.receive_upload(u.masked_upload(0, &ys[u.id], beta, &p));
    }
    server.close_uploads();
    let req = server.unmask_request();
    let mut responses: Vec<UnmaskResponse> =
        users.iter().map(|u| u.respond_unmask(&req)).collect();
    for (_, s) in responses[0].seed_shares.iter_mut() {
        s.y[0] = field::add(s.y[0], 1);
    }
    for r in responses {
        server.try_receive_response(r).unwrap();
    }
    let responses = server.take_responses();
    assert!(server.finish_round(0, &responses).is_err());
}

/// Recovery catalog: one two-faced survivor (honest upload, poisoned
/// unmask shares) against the frame driver. The attacked round must
/// complete **bit-exactly** equal to the honest reference with the
/// byzantine ids (injector + excluded equivocator) simply dropped, and
/// the ledger must account the recovery exactly: `excluded_users` is
/// the two-faced id, `retries` is one.
///
/// Cohort math: N = 10, t = 5. Byzantine prefix {0, 1}; id 0 injects
/// catalog frames, id 1 is two-faced. Nine users upload and respond,
/// one response poisoned — inside the unique-decoding radius
/// (9 ≥ t+1+2), so value poisoning is *identified*, and geometry
/// poisoning is flagged at ingest regardless.
fn assert_two_faced_recovers(secagg_proto: bool, kind: TwoFaced,
                             mode: ExecMode, shard: usize) {
    let alpha = if secagg_proto { 1.0 } else { 0.3 };
    let p = params(10, 500, alpha, 0.0);
    let ys = grads(p.n, p.d, 0x2f2f);
    let betas = vec![1.0 / p.n as f64; p.n];

    let mut reference = coordinator(secagg_proto, p, 177, mode, shard);
    let (want, ref_ledger) =
        reference.run_round(1, &ys, &betas, &[0, 1]).unwrap();
    assert_eq!(ref_ledger.retries, 0);

    let mut attacked = coordinator(secagg_proto, p, 177, mode, shard);
    let mut adv = Adversary::new(0.2, 0x7e57);
    adv.two_faced = vec![(1, kind)];
    let (got, ledger) = attacked
        .run_round_adversarial(1, &ys, &betas, &[], &mut adv)
        .unwrap_or_else(|e| {
            panic!("{kind:?}/{mode:?} secagg={secagg_proto} must \
                    recover, not abort: {e:#}")
        });

    assert_eq!(got, want,
               "{kind:?}/{mode:?} secagg={secagg_proto}: recovered \
                aggregate differs from honest-minus-excluded reference");
    assert_eq!(ledger.excluded_users, vec![1],
               "{kind:?}/{mode:?}: exactly the two-faced survivor is \
                excluded");
    assert_eq!(ledger.retries, 1,
               "{kind:?}/{mode:?}: one exclude-and-re-solicit pass");
    // Catalog injections from id 0 are all rejected; a geometry-poisoned
    // response is additionally rejected at ingest (value poisoning
    // passes ingest and is caught at reconstruction instead).
    let poisoned_rejects = match kind {
        TwoFaced::PoisonGeometry => 1,
        TwoFaced::PoisonValues => 0,
    };
    assert_eq!(ledger.rejected_frames, adv.injected + poisoned_rejects,
               "{kind:?}/{mode:?}: reject accounting");
}

#[test]
fn recovery_catalog_completes_bit_exactly_sparse_all_executors() {
    for &(mode, shard) in EXECUTORS {
        for kind in [TwoFaced::PoisonValues, TwoFaced::PoisonGeometry] {
            assert_two_faced_recovers(false, kind, mode, shard);
        }
    }
}

#[test]
fn recovery_catalog_completes_bit_exactly_secagg_all_executors() {
    for &(mode, shard) in EXECUTORS {
        for kind in [TwoFaced::PoisonValues, TwoFaced::PoisonGeometry] {
            assert_two_faced_recovers(true, kind, mode, shard);
        }
    }
}

/// `max_retries = 0` restores PR 3's detect-and-abort: the equivocator
/// is identified but the round must fail cleanly instead of retrying.
#[test]
fn max_retries_zero_aborts_cleanly_on_identified_equivocator() {
    let p = params(10, 300, 0.3, 0.0);
    let ys = grads(p.n, p.d, 0x2f30);
    let betas = vec![1.0 / p.n as f64; p.n];
    for kind in [TwoFaced::PoisonValues, TwoFaced::PoisonGeometry] {
        let mut c = coordinator(false, p, 178, ExecMode::Stealing, 64);
        c.max_retries = 0;
        let mut adv = Adversary::new(0.2, 0x7e58);
        adv.two_faced = vec![(1, kind)];
        let res = c.run_round_adversarial(0, &ys, &betas, &[], &mut adv);
        assert!(res.is_err(),
                "{kind:?}: retry budget 0 must abort, not recover");
    }
}

/// The server-level recovery driver (monolithic engine, closure
/// re-solicitation): poisoned share *values* with redundancy are
/// identified by reconstruction, the poisoner excluded, and the
/// aggregate finishes bit-exact to a reference round that never had
/// user 0 — for both protocols.
#[test]
fn poisoned_values_recover_via_server_recovery_driver() {
    let p = params(8, 300, 0.4, 0.0);
    let ys = grads(p.n, p.d, 0xbeed);
    let beta = 1.0 / p.n as f64;

    // --- sparse ---
    // Reference: identical cohort (same entropy), user 0 dropped.
    let (r_users, mut r_server) = sparse::setup(p, 5);
    r_server.begin_round();
    let mut scratch = vec![0u32; p.d];
    for u in r_users.iter().skip(1) {
        let plan = u.mask_plan(0, &p, &mut scratch);
        r_server.receive_upload(
            u.masked_upload(0, &ys[u.id], beta, &p, plan));
    }
    r_server.close_uploads();
    let req = r_server.unmask_request();
    for u in r_users.iter().skip(1) {
        r_server.try_receive_response(u.respond_unmask(&req)).unwrap();
    }
    let responses = r_server.take_responses();
    r_server.finish_round(0, &responses).unwrap();
    let want = r_server.aggregate_field().to_vec();

    // Attacked: everyone uploads; user 0 poisons every share word it
    // returns (valid geometry — ingest accepts it).
    let (users, mut server) = sparse::setup(p, 5);
    server.begin_round();
    for u in &users {
        let plan = u.mask_plan(0, &p, &mut scratch);
        server.receive_upload(
            u.masked_upload(0, &ys[u.id], beta, &p, plan));
    }
    server.close_uploads();
    let req = server.unmask_request();
    for u in &users {
        let mut resp = u.respond_unmask(&req);
        if u.id == 0 {
            for (_, s) in resp.seed_shares.iter_mut() {
                s.y[0] = field::add(s.y[0], 1);
            }
        }
        server.try_receive_response(resp).unwrap();
    }
    let (_, outcome) = server
        .finish_round_with_recovery(0, 2, |req| {
            users.iter().filter(|u| u.id != 0)
                .map(|u| u.respond_unmask(req)).collect()
        })
        .expect("value poisoning with redundancy must recover");
    assert_eq!(outcome.excluded, vec![0]);
    assert_eq!(outcome.retries, 1);
    assert_eq!(server.excluded(), &[0]);
    assert_eq!(server.aggregate_field(), &want[..],
               "recovered sparse aggregate != reference without user 0");

    // --- secagg ---
    let (r_users, mut r_server) = secagg::setup(p, 6);
    r_server.begin_round();
    for u in r_users.iter().skip(1) {
        r_server.receive_upload(u.masked_upload(0, &ys[u.id], beta, &p));
    }
    r_server.close_uploads();
    let req = r_server.unmask_request();
    for u in r_users.iter().skip(1) {
        r_server.try_receive_response(u.respond_unmask(&req)).unwrap();
    }
    let responses = r_server.take_responses();
    r_server.finish_round(0, &responses).unwrap();
    let want = r_server.aggregate_field().to_vec();

    let (users, mut server) = secagg::setup(p, 6);
    server.begin_round();
    for u in &users {
        server.receive_upload(u.masked_upload(0, &ys[u.id], beta, &p));
    }
    server.close_uploads();
    let req = server.unmask_request();
    for u in &users {
        let mut resp = u.respond_unmask(&req);
        if u.id == 0 {
            for (_, s) in resp.seed_shares.iter_mut() {
                s.y[0] = field::add(s.y[0], 1);
            }
        }
        server.try_receive_response(resp).unwrap();
    }
    let (_, outcome) = server
        .finish_round_with_recovery(0, 2, |req| {
            users.iter().filter(|u| u.id != 0)
                .map(|u| u.respond_unmask(req)).collect()
        })
        .expect("secagg value poisoning with redundancy must recover");
    assert_eq!(outcome.excluded, vec![0]);
    assert_eq!(outcome.retries, 1);
    assert_eq!(server.aggregate_field(), &want[..],
               "recovered secagg aggregate != reference without user 0");
}

/// Equivocation-by-geometry against the server recovery driver: the
/// re-stamped response is rejected *and flagged* at ingest, so recovery
/// excludes the equivocator without spending a finish attempt on it.
#[test]
fn geometry_equivocator_is_flagged_and_excluded_at_ingest() {
    let p = params(8, 250, 0.4, 0.0);
    let ys = grads(p.n, p.d, 0x6e00);
    let beta = 1.0 / p.n as f64;
    let (users, mut server) = sparse::setup(p, 7);
    server.begin_round();
    let mut scratch = vec![0u32; p.d];
    for u in &users {
        let plan = u.mask_plan(0, &p, &mut scratch);
        server.receive_upload(
            u.masked_upload(0, &ys[u.id], beta, &p, plan));
    }
    server.close_uploads();
    let req = server.unmask_request();
    for u in &users {
        let mut resp = u.respond_unmask(&req);
        if u.id == 2 {
            for (_, s) in resp.seed_shares.iter_mut() {
                s.x += 1; // wrong evaluation point: geometry forgery
            }
            assert!(server.try_receive_response(resp).is_err());
        } else {
            server.try_receive_response(resp).unwrap();
        }
    }
    let (_, outcome) = server
        .finish_round_with_recovery(0, 1, |req| {
            users.iter().filter(|u| u.id != 2)
                .map(|u| u.respond_unmask(req)).collect()
        })
        .expect("geometry equivocation must recover");
    assert_eq!(outcome.excluded, vec![2]);
    assert_eq!(outcome.retries, 1);
}

/// Raw hostile bytes straight into the frame ingest: any byte soup must
/// come back as a typed error, never a panic, and never mutate state.
#[test]
fn frame_ingest_survives_random_byte_storm() {
    let p = params(6, 100, 0.5, 0.0);
    let (_, mut server) = sparse::setup(p, 3);
    server.begin_round();
    let mut rng = ChaCha20Rng::from_seed_u64(0x57a9);
    for _ in 0..500 {
        let len = (rng.next_u32() as usize) % 200;
        let buf: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        let from = rng.next_u32() as usize % p.n;
        // Hostile bytes: either rejected, or (vanishingly unlikely) a
        // well-formed frame — but never a panic.
        let _ = server.ingest_frame(from, &buf);
    }
    assert!(server.aggregate_field().iter().all(|&v| v == 0),
            "random bytes must not reach the aggregate");
}
