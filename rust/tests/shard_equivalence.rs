//! Differential equivalence of every unmask executor against the
//! monolithic reference path, over full protocol rounds: random `N`,
//! `d`, `alpha`, dropout sets, shard sizes (including
//! `d % shard_size != 0` remainders and shard_size > d), and — through a
//! lowered acceptance bound — the rejection-sampling carry logic that
//! real keystreams only exercise with probability ~1.2e-9 per word.
//!
//! Two engines are pinned against the monolithic anchor:
//!
//! * the **windowed** shard pipeline (PR 1's bounded-memory reference);
//! * the **work-stealing** two-tier executor — the scheduler-determinism
//!   suite: output must be bit-exact across random worker counts (1..8),
//!   shard sizes, and forced uneven stealing (one long dense stream
//!   plus many short sparse streams — the mix where steal order varies
//!   most between runs).
//!
//! Together the property tests here run > 150 seeded cases; every one
//! asserts **bit-exact** field-level equality, not approximate closeness.

use sparsesecagg::exec::{jobs as exec_jobs, Executor};
use sparsesecagg::field;
use sparsesecagg::prg::{ChaCha20Rng, Seed};
use sparsesecagg::protocol::messages::UnmaskResponse;
use sparsesecagg::protocol::shard::{self, MaskJob, ShardConfig};
use sparsesecagg::protocol::{secagg, sparse, Params};
use sparsesecagg::testutil::prop;

fn rand_seed(rng: &mut ChaCha20Rng) -> Seed {
    let mut w = [0u32; 8];
    for v in w.iter_mut() {
        *v = rng.next_field();
    }
    Seed(w)
}

fn random_grads(rng: &mut ChaCha20Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..d).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
        .collect()
}

/// Random dropout set strictly below the ⌊N/2⌋+1 survivor threshold.
fn random_dropouts(rng: &mut ChaCha20Rng, n: usize) -> Vec<usize> {
    let max_drop = n - (n / 2 + 1);
    let k = (rng.next_u32() as usize) % (max_drop + 1);
    let mut ids: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = (rng.next_u32() as usize) % (i + 1);
        ids.swap(i, j);
    }
    ids.truncate(k);
    ids
}

/// Shard sizes that stress remainders: tiny, non-divisors, larger than d.
fn random_shard_size(rng: &mut ChaCha20Rng, d: usize) -> usize {
    match rng.next_u32() % 4 {
        0 => 1 + (rng.next_u32() as usize % 7),
        1 => 1 + (rng.next_u32() as usize % d.max(2)),
        2 => d + 1 + (rng.next_u32() as usize % 64),
        _ => {
            // deliberately a non-divisor when possible
            let s = 2 + (rng.next_u32() as usize % (d.max(3) - 1));
            if d % s == 0 { s + 1 } else { s }
        }
    }
}

#[test]
fn sparse_round_sharded_equals_monolithic() {
    prop(35, |rng| {
        let n = 4 + (rng.next_u32() as usize % 8);
        let d = 100 + (rng.next_u32() as usize % 900);
        let alpha = 0.05 + 0.6 * rng.next_f32() as f64;
        let theta = 0.3 * rng.next_f32() as f64;
        let params = Params { n, d, alpha, theta, c: 2048.0 };
        let entropy = 500 + rng.next_u32() as u64;
        let round = rng.next_u32() % 50;
        let shard_size = random_shard_size(rng, d);
        let threads = 1 + (rng.next_u32() as usize % 4);
        let cfg = ShardConfig::new(shard_size, threads);

        let (users, mut mono) = sparse::setup(params, entropy);
        let mut sharded = sparse::Server::new(params);
        let ads: Vec<_> = users.iter().map(|u| u.advertise()).collect();
        sharded.collect_keys(&ads);

        let ys = random_grads(rng, n, d);
        let beta = 1.0 / n as f64;
        let dropped = random_dropouts(rng, n);

        mono.begin_round();
        sharded.begin_round();
        let mut scratch = vec![0u32; d];
        for u in users.iter().filter(|u| !dropped.contains(&u.id)) {
            let plan = u.mask_plan(round, &params, &mut scratch);
            let up = u.masked_upload(round, &ys[u.id], beta, &params, plan);
            mono.receive_upload(up.clone());
            sharded.receive_upload(up);
        }
        let req = mono.unmask_request();
        let responses: Vec<UnmaskResponse> = users
            .iter()
            .filter(|u| !dropped.contains(&u.id))
            .map(|u| u.respond_unmask(&req))
            .collect();

        let out_mono = mono.finish_round(round, &responses).unwrap();
        let (out_shard, stats) =
            sharded.finish_round_sharded(round, &responses, &cfg).unwrap();

        assert_eq!(mono.aggregate_field(), sharded.aggregate_field(),
                   "field aggregate diverged: n={n} d={d} alpha={alpha:.2} \
                    shard={shard_size} threads={threads} \
                    dropped={dropped:?}");
        assert_eq!(out_mono, out_shard, "dequantized output diverged");
        assert!(stats.jobs > 0);
    });
}

#[test]
fn secagg_round_sharded_equals_monolithic() {
    prop(30, |rng| {
        let n = 4 + (rng.next_u32() as usize % 7);
        let d = 64 + (rng.next_u32() as usize % 700);
        let theta = 0.3 * rng.next_f32() as f64;
        let params = Params { n, d, alpha: 1.0, theta, c: 1024.0 };
        let entropy = 900 + rng.next_u32() as u64;
        let round = rng.next_u32() % 50;
        let shard_size = random_shard_size(rng, d);
        let cfg = ShardConfig::new(shard_size, 3);

        let (users, mut mono) = secagg::setup(params, entropy);
        let mut sharded = secagg::Server::new(params);
        let ads: Vec<_> = users.iter().map(|u| u.advertise()).collect();
        sharded.collect_keys(&ads);

        let ys = random_grads(rng, n, d);
        let beta = 1.0 / n as f64;
        let dropped = random_dropouts(rng, n);

        mono.begin_round();
        sharded.begin_round();
        for u in users.iter().filter(|u| !dropped.contains(&u.id)) {
            let up = u.masked_upload(round, &ys[u.id], beta, &params);
            mono.receive_upload(up.clone());
            sharded.receive_upload(up);
        }
        let req = mono.unmask_request();
        let responses: Vec<UnmaskResponse> = users
            .iter()
            .filter(|u| !dropped.contains(&u.id))
            .map(|u| u.respond_unmask(&req))
            .collect();

        let out_mono = mono.finish_round(round, &responses).unwrap();
        let (out_shard, _stats) =
            sharded.finish_round_sharded(round, &responses, &cfg).unwrap();

        assert_eq!(mono.aggregate_field(), sharded.aggregate_field(),
                   "n={n} d={d} shard={shard_size} dropped={dropped:?}");
        assert_eq!(out_mono, out_shard);
    });
}

/// Drive the rejection-carry machinery hard: with the acceptance bound
/// lowered to ~q/2, roughly half the keystream words are "rejected", so
/// every shard boundary misaligns and the sequential tail completion
/// runs on every stream. The sharded result must still match a
/// straightforward sequential rejection-sampling reference.
#[test]
fn rejection_carries_stay_bit_exact() {
    prop(25, |rng| {
        let d = 40 + (rng.next_u32() as usize % 300);
        let shard_size = 1 + (rng.next_u32() as usize % 60);
        let cfg = ShardConfig::new(shard_size, 2);
        // Bound between ~25% and ~75% acceptance.
        let accept = (1u32 << 30) + rng.next_u32() % (1u32 << 31);
        let seed = rand_seed(rng);
        let (stream, round) = (1 + rng.next_u32() % 4, rng.next_u32() % 9);
        let add = rng.next_u32() & 1 == 0;
        // Random sparse coords on odd cases, dense on even.
        let coords: Option<Vec<u32>> = if rng.next_u32() & 1 == 0 {
            None
        } else {
            Some((0..d as u32).filter(|_| rng.next_f32() < 0.3).collect())
        };

        let base: Vec<u32> = (0..d).map(|_| rng.next_field()).collect();

        // Sequential reference: scan words from the stream start,
        // keeping words < accept, applying element k at coordinate k
        // (dense) or coords[k].
        let mut want = base.clone();
        {
            let len = coords.as_ref().map_or(d, |c| c.len());
            let mut src = ChaCha20Rng::new(seed, stream, round);
            let mut k = 0usize;
            while k < len {
                let w = src.next_u32();
                if w >= accept {
                    continue;
                }
                let l = coords.as_ref().map_or(k, |c| c[k] as usize);
                want[l] = if add {
                    field::add(want[l], w)
                } else {
                    field::sub(want[l], w)
                };
                k += 1;
            }
        }

        let mut got = base;
        let stats = shard::apply_stream_for_test(
            &mut got, seed, stream, round, add, coords.as_deref(), &cfg,
            accept);
        assert_eq!(got, want,
                   "d={d} shard={shard_size} accept={accept:#x}");
        // With ~50% rejection the tail must actually have run (unless the
        // stream was empty).
        let len = coords.as_ref().map_or(d, |c| c.len());
        if len > 8 {
            assert!(stats.rejection_carries > 0,
                    "expected rejection carries at accept={accept:#x}");
        }
    });
}

/// Scheduler determinism, full protocol rounds: the work-stealing
/// executor must produce the bit-exact monolithic aggregate whatever the
/// worker count (1..8), shard size, or steal interleaving. Covers both
/// executor consumers — the client phase (per-user tier-1 tasks on
/// worker arenas) runs inside `run_round`-equivalent server feeding.
#[test]
fn sparse_round_stealing_equals_monolithic() {
    prop(25, |rng| {
        let n = 4 + (rng.next_u32() as usize % 8);
        let d = 100 + (rng.next_u32() as usize % 900);
        let alpha = 0.05 + 0.6 * rng.next_f32() as f64;
        let theta = 0.3 * rng.next_f32() as f64;
        let params = Params { n, d, alpha, theta, c: 2048.0 };
        let entropy = 4_000 + rng.next_u32() as u64;
        let round = rng.next_u32() % 50;
        let threads = 1 + (rng.next_u32() as usize % 8);
        let exec = Executor::new(threads);
        let cfg = ShardConfig::new(random_shard_size(rng, d), threads);

        let (users, mut mono) = sparse::setup(params, entropy);
        let mut stolen = sparse::Server::new(params);
        let ads: Vec<_> = users.iter().map(|u| u.advertise()).collect();
        stolen.collect_keys(&ads);

        let ys = random_grads(rng, n, d);
        let beta = 1.0 / n as f64;
        let dropped = random_dropouts(rng, n);

        mono.begin_round();
        stolen.begin_round();
        let mut scratch = vec![0u32; d];
        for u in users.iter().filter(|u| !dropped.contains(&u.id)) {
            let plan = u.mask_plan(round, &params, &mut scratch);
            let up = u.masked_upload(round, &ys[u.id], beta, &params, plan);
            mono.receive_upload(up.clone());
            stolen.receive_upload(up);
        }
        let req = mono.unmask_request();
        let responses: Vec<UnmaskResponse> = users
            .iter()
            .filter(|u| !dropped.contains(&u.id))
            .map(|u| u.respond_unmask(&req))
            .collect();

        let out_mono = mono.finish_round(round, &responses).unwrap();
        let (out_stolen, stats) = stolen
            .finish_round_stealing(round, &responses, &cfg, &exec)
            .unwrap();

        assert_eq!(mono.aggregate_field(), stolen.aggregate_field(),
                   "field aggregate diverged: n={n} d={d} alpha={alpha:.2} \
                    shard={} threads={threads} dropped={dropped:?}",
                   cfg.shard_size);
        assert_eq!(out_mono, out_stolen, "dequantized output diverged");
        assert!(stats.jobs > 0);
    });
}

#[test]
fn secagg_round_stealing_equals_monolithic() {
    prop(20, |rng| {
        let n = 4 + (rng.next_u32() as usize % 7);
        let d = 64 + (rng.next_u32() as usize % 700);
        let theta = 0.3 * rng.next_f32() as f64;
        let params = Params { n, d, alpha: 1.0, theta, c: 1024.0 };
        let entropy = 7_000 + rng.next_u32() as u64;
        let round = rng.next_u32() % 50;
        let threads = 1 + (rng.next_u32() as usize % 8);
        let exec = Executor::new(threads);
        let cfg = ShardConfig::new(random_shard_size(rng, d), threads);

        let (users, mut mono) = secagg::setup(params, entropy);
        let mut stolen = secagg::Server::new(params);
        let ads: Vec<_> = users.iter().map(|u| u.advertise()).collect();
        stolen.collect_keys(&ads);

        let ys = random_grads(rng, n, d);
        let beta = 1.0 / n as f64;
        let dropped = random_dropouts(rng, n);

        mono.begin_round();
        stolen.begin_round();
        for u in users.iter().filter(|u| !dropped.contains(&u.id)) {
            let up = u.masked_upload(round, &ys[u.id], beta, &params);
            mono.receive_upload(up.clone());
            stolen.receive_upload(up);
        }
        let req = mono.unmask_request();
        let responses: Vec<UnmaskResponse> = users
            .iter()
            .filter(|u| !dropped.contains(&u.id))
            .map(|u| u.respond_unmask(&req))
            .collect();

        let out_mono = mono.finish_round(round, &responses).unwrap();
        let (out_stolen, _stats) = stolen
            .finish_round_stealing(round, &responses, &cfg, &exec)
            .unwrap();

        assert_eq!(mono.aggregate_field(), stolen.aggregate_field(),
                   "n={n} d={d} threads={threads} dropped={dropped:?}");
        assert_eq!(out_mono, out_stolen);
    });
}

/// Forced uneven stealing: one long dense stream (splits into many
/// tier-2 shard tasks) plus many short sparse streams (tier-1 leaves).
/// Whichever worker opens the dense stream floods its own deque while
/// the short jobs sit on others' — maximum steal-order variance. The
/// result must stay bit-exact at every worker count.
#[test]
fn stealing_uneven_mix_long_dense_plus_short_sparse_is_bit_exact() {
    let d = 40_000usize;
    let mut rng = ChaCha20Rng::from_seed_u64(0xfeed_1234);
    let mut jobs: Vec<MaskJob> = vec![MaskJob::Dense {
        seed: rand_seed(&mut rng),
        stream: 1,
        round: 2,
        add: true,
    }];
    for _ in 0..48 {
        // short sparse streams: ~0.5% of d each
        let indices: Vec<u32> = (0..d as u32)
            .filter(|_| rng.next_f32() < 0.005)
            .collect();
        jobs.push(MaskJob::Indexed {
            seed: rand_seed(&mut rng),
            stream: 3,
            round: 2,
            add: rng.next_u32() & 1 == 0,
            indices,
        });
    }
    let base: Vec<u32> = (0..d).map(|_| rng.next_field()).collect();
    let mut mono = base.clone();
    for job in &jobs {
        shard::apply_job_monolithic(&mut mono, job);
    }
    for threads in 1..=8usize {
        let exec = Executor::new(threads);
        let cfg = ShardConfig::new(1 << 12, threads);
        let mut stolen = base.clone();
        let stats = exec_jobs::apply_jobs_stealing(&mut stolen, &jobs, &cfg,
                                                   &exec);
        assert_eq!(stolen, mono, "threads={threads}");
        assert_eq!(stats.jobs, jobs.len());
        // dense stream alone contributes ceil(40000/4096) tier-2 tasks
        assert!(stats.shards >= jobs.len() + 9);
        assert_eq!(stats.rejection_carries, 0);
    }
}

/// Rejection carries under real stealing: lowered acceptance bound so
/// every shard boundary misaligns, across executors of 1..6 workers,
/// with dense and sparse jobs in the same batch.
#[test]
fn stealing_rejection_carries_stay_bit_exact() {
    prop(18, |rng| {
        let d = 120 + (rng.next_u32() as usize % 400);
        let threads = 1 + (rng.next_u32() as usize % 6);
        let exec = Executor::new(threads);
        let cfg = ShardConfig::new(1 + (rng.next_u32() as usize % 50),
                                   threads);
        let accept = (1u32 << 30) + rng.next_u32() % (1u32 << 31);
        let njobs = 1 + (rng.next_u32() as usize % 4);
        let jobs: Vec<MaskJob> = (0..njobs)
            .map(|j| {
                let seed = rand_seed(rng);
                let add = rng.next_u32() & 1 == 0;
                // Job 0 is always dense: at d ≥ 120 words and ≤ 75%
                // acceptance, a zero-rejection stream is ~impossible, so
                // the carries > 0 assertion below cannot flake.
                if j == 0 || rng.next_u32() & 1 == 0 {
                    MaskJob::Dense { seed, stream: 2, round: 5, add }
                } else {
                    MaskJob::Indexed {
                        seed,
                        stream: 2,
                        round: 5,
                        add,
                        indices: (0..d as u32)
                            .filter(|_| rng.next_f32() < 0.3)
                            .collect(),
                    }
                }
            })
            .collect();
        let base: Vec<u32> = (0..d).map(|_| rng.next_field()).collect();

        // Sequential rejection-sampling reference, one job at a time.
        let mut want = base.clone();
        for job in &jobs {
            let (seed, coords, add) = match job {
                MaskJob::Dense { seed, add, .. } => (*seed, None, *add),
                MaskJob::Indexed { seed, add, indices, .. } => {
                    (*seed, Some(indices), *add)
                }
            };
            let len = coords.map_or(d, |c| c.len());
            let mut src = ChaCha20Rng::new(seed, 2, 5);
            let mut k = 0usize;
            while k < len {
                let w = src.next_u32();
                if w >= accept {
                    continue;
                }
                let l = coords.map_or(k, |c| c[k] as usize);
                want[l] = if add {
                    field::add(want[l], w)
                } else {
                    field::sub(want[l], w)
                };
                k += 1;
            }
        }

        let mut got = base;
        let stats = exec_jobs::apply_jobs_stealing_accept(
            &mut got, &jobs, &cfg, &exec, accept);
        assert_eq!(got, want, "d={d} threads={threads} accept={accept:#x}");
        assert!(stats.rejection_carries > 0,
                "carry machinery must have run at accept={accept:#x}");
    });
}

/// The engine respects its own memory contract: scratch is bounded by
/// threads·shard regardless of d.
#[test]
fn window_scratch_is_independent_of_d() {
    for &d in &[1usize << 14, 1 << 16, 1 << 18] {
        let cfg = ShardConfig::new(256, 4);
        let mut agg = vec![0u32; d];
        let jobs = vec![shard::MaskJob::Dense {
            seed: Seed([8; 8]),
            stream: 1,
            round: 0,
            add: true,
        }];
        let stats = shard::apply_jobs_sharded(&mut agg, &jobs, &cfg);
        assert!(stats.peak_scratch_bytes <= 4 * 256 * 8,
                "d={d}: scratch {}", stats.peak_scratch_bytes);
        assert_eq!(stats.shards, d.div_ceil(256));
    }
}
