//! Integration tests over the full stack: artifacts → trainer → protocol
//! → aggregation → accuracy. Requires `make artifacts`.

use sparsesecagg::coordinator::{Coordinator, ProtocolKind};
use sparsesecagg::fl::{run_fl, FlConfig, Trainer};
use sparsesecagg::protocol::Params;

fn trainer(model: &str, with_qm: bool) -> Option<Trainer> {
    match Trainer::load("artifacts", model, with_qm) {
        Ok(t) => Some(t),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn federated_training_learns_with_sparse_protocol() {
    let Some(t) = trainer("mlp", false) else { return };
    let cfg = FlConfig {
        model: "mlp".into(),
        users: 6,
        rounds: 8,
        samples_per_user: 80,
        test_samples: 200,
        alpha: 0.3,
        theta: 0.1,
        lr: 0.05,
        ..FlConfig::default()
    };
    let run = run_fl(&cfg, &t).unwrap();
    assert_eq!(run.history.len(), 8);
    assert!(run.final_accuracy > 0.5,
            "accuracy after 8 rounds: {}", run.final_accuracy);
    // Loss must drop from round 0.
    let first = run.history.first().unwrap().mean_local_loss;
    let last = run.history.last().unwrap().mean_local_loss;
    assert!(last < first);
    // Comm bytes are recorded every round and sparse (≪ 4d).
    for r in &run.history {
        assert!(r.max_up_bytes > 0);
        assert!(r.max_up_bytes < 4 * t.m.d);
    }
}

#[test]
fn federated_training_learns_with_secagg_baseline() {
    let Some(t) = trainer("mlp", false) else { return };
    let cfg = FlConfig {
        model: "mlp".into(),
        protocol: ProtocolKind::SecAgg,
        users: 6,
        rounds: 6,
        samples_per_user: 80,
        test_samples: 200,
        theta: 0.0,
        lr: 0.05,
        ..FlConfig::default()
    };
    let run = run_fl(&cfg, &t).unwrap();
    assert!(run.final_accuracy > 0.5, "acc={}", run.final_accuracy);
    // Dense uploads: ≥ 4d bytes per user per round.
    assert!(run.history[0].max_up_bytes >= 4 * t.m.d);
}

#[test]
fn hlo_quantmask_path_trains_identically() {
    // Same config, HLO vs native MaskedInput: histories must agree in
    // bytes and (bit-identical masking ⇒ identical arithmetic) accuracy.
    let Some(t) = trainer("cnn_mnist_small", true) else { return };
    let base = FlConfig {
        model: "cnn_mnist_small".into(),
        users: 4,
        rounds: 2,
        samples_per_user: 56,
        test_samples: 200,
        theta: 0.0,
        ..FlConfig::default()
    };
    let native = run_fl(&base, &t).unwrap();
    let hlo = run_fl(&FlConfig { use_hlo_quantmask: true, ..base.clone() },
                     &t).unwrap();
    for (a, b) in native.history.iter().zip(&hlo.history) {
        assert_eq!(a.max_up_bytes, b.max_up_bytes);
        assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits(),
                   "round {}: accuracy diverged between paths", a.round);
    }
}

#[test]
fn noniid_training_is_harder_but_learns() {
    let Some(t) = trainer("mlp", false) else { return };
    let cfg = FlConfig {
        model: "mlp".into(),
        users: 6,
        rounds: 8,
        samples_per_user: 80,
        test_samples: 200,
        alpha: 0.3,
        theta: 0.0,
        lr: 0.05,
        iid: false,
        ..FlConfig::default()
    };
    let run = run_fl(&cfg, &t).unwrap();
    assert!(run.final_accuracy > 0.3, "acc={}", run.final_accuracy);
}

#[test]
fn target_accuracy_stops_early() {
    let Some(t) = trainer("mlp", false) else { return };
    let cfg = FlConfig {
        model: "mlp".into(),
        users: 4,
        rounds: 30,
        samples_per_user: 80,
        test_samples: 200,
        alpha: 0.5,
        theta: 0.0,
        lr: 0.05,
        target_accuracy: Some(0.4),
        ..FlConfig::default()
    };
    let run = run_fl(&cfg, &t).unwrap();
    assert!(run.reached_target_at.is_some(), "never reached 40%");
    assert!(run.history.len() < 30);
}

#[test]
fn dp_composition_trains_with_modest_penalty() {
    // DP extension (§II / ref. [17]): clipping + √T-reduced Gaussian
    // noise composes with the protocol; training still learns at a
    // loose ε, degrading gracefully vs the noiseless run.
    let Some(t) = trainer("mlp", false) else { return };
    let base = FlConfig {
        model: "mlp".into(),
        users: 8,
        rounds: 8,
        samples_per_user: 80,
        test_samples: 200,
        alpha: 0.3,
        theta: 0.0,
        lr: 0.05,
        ..FlConfig::default()
    };
    let clean = run_fl(&base, &t).unwrap();
    // Loose ε: per-coordinate σ_total ≈ 0.005 ≪ update scale, so
    // training must still learn; tight ε=2 must hurt (monotone in ε).
    let loose = run_fl(&FlConfig {
        dp_epsilon: Some(500.0),
        dp_clip: 0.5,
        ..base.clone()
    }, &t).unwrap();
    let tight = run_fl(&FlConfig {
        dp_epsilon: Some(2.0),
        dp_clip: 0.5,
        rounds: 4,
        ..base.clone()
    }, &t).unwrap();
    assert!(loose.final_accuracy > 0.4,
            "loose-ε DP run collapsed: {}", loose.final_accuracy);
    assert!(loose.final_accuracy <= clean.final_accuracy + 0.08,
            "noise should not help: {} vs {}",
            loose.final_accuracy, clean.final_accuracy);
    assert!(tight.final_accuracy < loose.final_accuracy,
            "tight ε must cost accuracy: {} vs {}",
            tight.final_accuracy, loose.final_accuracy);
    assert!(tight.history.iter().all(|r| r.mean_local_loss.is_finite()));
}

#[test]
fn client_sampling_composes_with_sparsification() {
    let Some(t) = trainer("mlp", false) else { return };
    let cfg = FlConfig {
        model: "mlp".into(),
        users: 8,
        rounds: 8,
        samples_per_user: 80,
        test_samples: 200,
        alpha: 0.3,
        theta: 0.0,
        lr: 0.05,
        participation: 0.7,
        ..FlConfig::default()
    };
    let run = run_fl(&cfg, &t).unwrap();
    assert!(run.final_accuracy > 0.4, "acc={}", run.final_accuracy);
    // some rounds must actually have sampled-out users
    assert!(run.history.iter().any(|r| r.dropped > 0));
}

#[test]
fn table1_regime_on_real_cifar_arch() {
    // Table I at N=25 with the real CIFAR-architecture d: one protocol
    // round each, compare measured per-user upload.
    let Some(t) = trainer("cnn_cifar", false) else { return };
    let d = t.m.d;
    let n = 25;
    let params = Params { n, d, alpha: 0.1, theta: 0.0, c: 1024.0 };
    let ys: Vec<Vec<f32>> = vec![vec![0.001; d]; n];
    let betas = vec![1.0 / n as f64; n];

    let mut sec = Coordinator::new_secagg(params, 3);
    let (_, lsec) = sec.run_round(0, &ys, &betas, &[]).unwrap();
    let mut spa = Coordinator::new_sparse(params, 3);
    let (_, lspa) = spa.run_round(0, &ys, &betas, &[]).unwrap();

    // SecAgg ≈ 4d ≈ 0.68 MB; Sparse ≈ α·4d + d/8 ⇒ ratio ≈ 8×.
    let ratio = lsec.max_up() as f64 / lspa.max_up() as f64;
    assert!(lsec.max_up() >= 4 * d);
    assert!(ratio > 6.5 && ratio < 10.0, "ratio={ratio}");
}
