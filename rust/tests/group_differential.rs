//! Grouped-aggregation differential suite (CI-gated by name): the
//! locks that make the group-tree refactor safe to ship.
//!
//! 1. `groups = 1` is **bit-exactly** the pre-refactor flat round —
//!    aggregate bits, per-user byte ledger, simulated clock, scheduler
//!    counters — across both protocols and all three unmask executors.
//! 2. For G > 1 the grouped round equals [`tree_reduce`] over the G
//!    independent flat group rounds, bit-exactly, for both protocols
//!    (the determinism anchor; a flat N-user round is *not* the
//!    reference — f32 addition is not associative and per-group
//!    quantization scales depend on n).
//! 3. The scaling claim of the refactor: at N = 4096 with
//!    `group_size = 64`, the measured per-user upload bytes in the
//!    merged [`RoundLedger`] are within 2× of a flat N = 64 round
//!    (they are in fact equal — a grouped user's bytes come only from
//!    its own group's round).
//! 4. The seeded per-group dropout + byzantine matrix: concentrated
//!    vs spread placement, with failures confined to exactly the
//!    groups whose honest survivor count falls below t(n_g) + 1.

use sparsesecagg::coordinator::grouped::group_entropy;
use sparsesecagg::coordinator::{Coordinator, GroupedCoordinator,
                                ProtocolKind};
use sparsesecagg::exec::ExecMode;
use sparsesecagg::prg::ChaCha20Rng;
use sparsesecagg::protocol::group::{place_byzantine, tree_reduce,
                                    GroupLayout, Placement};
use sparsesecagg::protocol::Params;

/// The three round-hot execution engines, with the shard size that
/// selects each (0 = the monolithic reference path).
const EXECUTORS: &[(ExecMode, usize)] = &[
    (ExecMode::Stealing, 64),
    (ExecMode::Windowed, 64),
    (ExecMode::Monolithic, 0),
];

const PROTOCOLS: &[ProtocolKind] =
    &[ProtocolKind::Sparse, ProtocolKind::SecAgg];

fn random_grads(rng: &mut ChaCha20Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..d).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// SecAgg ignores sparsification; mirror the fl driver's convention of
/// pinning α = 1 for the dense baseline so the two protocols run on
/// comparable parameters.
fn params_for(kind: ProtocolKind, n: usize, d: usize) -> Params {
    let alpha = match kind {
        ProtocolKind::Sparse => 0.35,
        ProtocolKind::SecAgg => 1.0,
    };
    Params { n, d, alpha, theta: 0.2, c: 1024.0 }
}

fn mk_flat(kind: ProtocolKind, p: Params, entropy: u64) -> Coordinator {
    match kind {
        ProtocolKind::Sparse => Coordinator::new_sparse(p, entropy),
        ProtocolKind::SecAgg => Coordinator::new_secagg(p, entropy),
    }
}

fn mk_grouped(kind: ProtocolKind, p: Params, entropy: u64,
              layout: GroupLayout) -> GroupedCoordinator {
    match kind {
        ProtocolKind::Sparse => {
            GroupedCoordinator::new_sparse(p, entropy, layout)
        }
        ProtocolKind::SecAgg => {
            GroupedCoordinator::new_secagg(p, entropy, layout)
        }
    }
}

/// Lock 1: `groups = 1` is the flat path verbatim — across both
/// protocols, all three executors, and two consecutive rounds (the
/// round counter feeds every mask PRG stream).
#[test]
fn single_group_bit_exact_vs_flat_full_matrix() {
    for &kind in PROTOCOLS {
        for &(mode, shard) in EXECUTORS {
            let p = params_for(kind, 10, 500);
            let mut rng = ChaCha20Rng::from_seed_u64(0x6d1f);
            let ys = random_grads(&mut rng, p.n, p.d);
            let betas = vec![1.0 / p.n as f64; p.n];
            let dropped = vec![1usize, 6];

            let mut flat = mk_flat(kind, p, 404);
            flat.exec_mode = mode;
            flat.shard_size = shard;
            let mut grouped =
                mk_grouped(kind, p, 404, GroupLayout::groups(p.n, 1));
            grouped.for_each_group(|c| {
                c.exec_mode = mode;
                c.shard_size = shard;
            });
            assert_eq!(grouped.setup_ledger.up_bytes,
                       flat.setup_ledger.up_bytes,
                       "{kind:?}/{mode:?}: setup ledger diverged");

            for round in 0..2u32 {
                let (fa, fl) = flat
                    .run_round(round, &ys, &betas, &dropped)
                    .unwrap();
                let out = grouped
                    .run_round(round, &ys, &betas, &dropped)
                    .unwrap();
                let ctx = format!("{kind:?}/{mode:?} round {round}");
                assert!(out.failed.is_empty(), "{ctx}: {:?}", out.failed);
                assert_eq!(bits(&out.aggregate), bits(&fa),
                           "{ctx}: aggregate bits diverged");
                assert_eq!(out.ledger.up_bytes, fl.up_bytes,
                           "{ctx}: per-user upload bytes diverged");
                assert_eq!(out.ledger.down_bytes, fl.down_bytes,
                           "{ctx}: per-user download bytes diverged");
                assert_eq!(out.ledger.comm_time_s.to_bits(),
                           fl.comm_time_s.to_bits(),
                           "{ctx}: simulated clock diverged");
                assert_eq!(out.ledger.client_tasks, fl.client_tasks,
                           "{ctx}: scheduler accounting diverged");
                assert_eq!(out.ledger.phases.len(), fl.phases.len(),
                           "{ctx}: phase breakdown diverged");
            }
        }
    }
}

/// Lock 2: the G > 1 grouped round is bit-exactly [`tree_reduce`] over
/// the G independent flat group rounds, for both protocols.
#[test]
fn grouped_round_equals_tree_reduced_flat_group_rounds() {
    for &kind in PROTOCOLS {
        let p = params_for(kind, 12, 300);
        let entropy = 7117u64;
        let mut rng = ChaCha20Rng::from_seed_u64(0x9e0);
        let ys = random_grads(&mut rng, p.n, p.d);
        let betas = vec![1.0 / p.n as f64; p.n];
        let dropped = vec![2usize, 9];

        let mut grouped =
            mk_grouped(kind, p, entropy, GroupLayout::groups(p.n, 3));
        let out = grouped.run_round(0, &ys, &betas, &dropped).unwrap();
        assert!(out.failed.is_empty(), "{kind:?}: {:?}", out.failed);

        // Reference: each group as its own flat cohort, with the same
        // per-group entropy derivation the grouped constructor uses
        // (pinned by `single_group_bit_exact_vs_flat_full_matrix`
        // through the g = 0 identity), reduced by the fixed tree.
        let layout = GroupLayout::groups(p.n, 3);
        let locals = layout.localize(&dropped);
        let mut parts = Vec::new();
        for g in 0..layout.count() {
            let (s, l) = (layout.start(g), layout.len(g));
            let mut flat = mk_flat(kind, Params { n: l, ..p },
                                   group_entropy(entropy, g));
            let (agg, _) = flat
                .run_round(0, &ys[s..s + l], &betas[s..s + l], &locals[g])
                .unwrap();
            parts.push(Some(agg));
        }
        let reference = tree_reduce(parts).unwrap();
        assert_eq!(bits(&out.aggregate), bits(&reference),
                   "{kind:?}: grouped != tree-reduced flat rounds");
    }
}

/// Lock 3 (the point of the refactor): at N = 4096, `group_size = 64`,
/// a user's measured upload bytes equal the flat N = 64 round's — and
/// are therefore far below the flat-N growth curve. The acceptance
/// bound is 2×; the construction delivers exact equality.
#[test]
fn per_user_bytes_at_n4096_match_flat_64_user_round() {
    let d = 48; // tiny model: the claim is about N-scaling, not d
    let p_flat = params_for(ProtocolKind::Sparse, 64, d);
    let mut flat = Coordinator::new_sparse(p_flat, 12);
    let ys64: Vec<Vec<f32>> = vec![vec![0.02; d]; 64];
    let betas64 = vec![1.0 / 64.0; 64];
    let (_, ledger64) = flat.run_round(0, &ys64, &betas64, &[]).unwrap();

    let n = 4096usize;
    let p = params_for(ProtocolKind::Sparse, n, d);
    let mut grouped = GroupedCoordinator::new_sparse(
        p, 12, GroupLayout::of_size(n, 64));
    assert_eq!(grouped.layout().count(), 64);
    grouped.set_threads(1); // keep the 64-way fan-out light in CI
    let ys: Vec<Vec<f32>> = vec![vec![0.02; d]; n];
    let betas = vec![1.0 / n as f64; n];
    let out = grouped.run_round(0, &ys, &betas, &[]).unwrap();
    assert!(out.failed.is_empty(), "{:?}", out.failed);
    assert_eq!(out.ledger.up_bytes.len(), n);

    let grouped_max = out.ledger.max_up();
    let flat64_max = ledger64.max_up();
    assert!(grouped_max > 0 && flat64_max > 0);
    assert!(
        grouped_max <= 2 * flat64_max,
        "per-user upload at N=4096/group_size=64 ({grouped_max} B) \
         exceeds 2x the flat N=64 round ({flat64_max} B)"
    );
    // Setup (key exchange + Shamir shares) scales the same way.
    assert!(
        grouped.setup_ledger.max_up() <= 2 * flat.setup_ledger.max_up(),
        "setup bytes do not scale with the group size"
    );
}

/// Lock 4: the seeded per-group dropout + byzantine matrix. Expected
/// per-group outcomes are derived from the same seeded placement the
/// coordinator uses: a group fails exactly when its honest survivors
/// fall below t(n_g) + 1 (byzantine frames are shed at ingest, so a
/// byzantine user contributes nothing — like a dropout with teeth).
/// Concentrated placement starves one group and leaves the rest
/// untouched; spread placement dilutes the same budget.
#[test]
fn dropout_byzantine_matrix_confines_failures_per_group() {
    let n = 20usize;
    let groups = 4usize; // n_g = 5, quorum t + 1 = 3
    // floor(0.2 * 20) = round(0.2 * 20) = 4, so `adversaries` (floor)
    // and `honest_mask` (round) agree on the byzantine budget.
    let frac = 0.2f64;
    for (case, placement) in [
        Placement::Concentrated { group: 1 },
        Placement::Spread,
    ]
    .into_iter()
    .enumerate()
    {
        let seed = 0xb0b + case as u64;
        let p = params_for(ProtocolKind::Sparse, n, 200);
        let layout = GroupLayout::groups(n, groups);
        let mut grouped =
            GroupedCoordinator::new_sparse(p, 31, layout.clone());

        // One honest dropout in group 3 on top of the byzantine budget.
        let dropped = vec![layout.start(3)];
        // A byzantine frame injector contributes nothing (every catalog
        // frame is shed at ingest — `adversary` module contract), so a
        // group fails exactly when its honest survivors fall below
        // t(n_g) + 1. Derive the expected failure set from the same
        // seeded placement the coordinator uses.
        let per_group = place_byzantine(
            &layout, (frac * n as f64).floor() as usize, placement, seed);
        let expect_fail: Vec<usize> = (0..groups)
            .filter(|&g| {
                let nbyz = per_group[g].len();
                let honest_drops =
                    usize::from(g == 3 && !per_group[3].contains(&0));
                layout.len(g) - nbyz - honest_drops
                    < layout.len(g) / 2 + 1
            })
            .collect();
        if let Placement::Concentrated { group } = placement {
            // 4 byzantine of 5 leaves 1 honest < 3: the hit group must
            // be starved, so the matrix genuinely exercises confinement.
            assert_eq!(expect_fail, vec![group]);
        }

        let mask = grouped.honest_mask(frac, placement, seed);
        assert_eq!(mask.iter().filter(|&&h| !h).count(), 4,
                   "case {case}: honest mask disagrees with the budget");
        let mut advs = grouped.adversaries(frac, placement, seed);
        let out = grouped
            .run_round_adversarial(0, &random_grads(
                &mut ChaCha20Rng::from_seed_u64(seed), n, p.d),
                &vec![1.0 / n as f64; n], &dropped, &mut advs)
            .unwrap_or_else(|e| panic!("case {case}: {e:#}"));

        let failed: Vec<usize> =
            out.failed.iter().map(|(g, _)| *g).collect();
        assert_eq!(failed, expect_fail,
                   "case {case} ({placement:?}): failures not confined \
                    to the starved groups: {:?}", out.failed);
        assert_eq!(out.aggregate.len(), p.d);
        // Shed hostile frames are visible in the merged ledger — but
        // only from *surviving* groups (a failed group's ledger is
        // discarded with its subtree).
        let survivors_saw_attacks = per_group
            .iter()
            .enumerate()
            .any(|(g, ids)| !ids.is_empty() && !expect_fail.contains(&g));
        assert_eq!(out.ledger.rejected_frames > 0, survivors_saw_attacks,
                   "case {case}: merged rejected_frames disagrees with \
                    the placement");
    }
}
