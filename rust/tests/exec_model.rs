//! Named CI gate `Executor model check`: exhaustively verify the
//! executor scope protocol's soundness invariants over every bounded
//! interleaving (see `sparsesecagg::exec::model` for what is modeled
//! and why the bounds are sound to rely on).
//!
//! The full sweep — including the ≥ 3 worker / ≥ 4 task scenarios the
//! acceptance bound names — runs in release builds (the CI gate runs
//! `cargo test --release --test exec_model`) or when
//! `EXEC_MODEL_FULL=1` is set. Plain debug `cargo test` runs the ≤ 2
//! worker scenarios only, keeping the tier-1 suite fast; that subset
//! still covers spawn-from-task chains and panic abandonment.

use sparsesecagg::exec::model::{
    check_scenario, scenarios, DEFAULT_MAX_STATES,
};

fn run_full() -> bool {
    cfg!(not(debug_assertions)) || std::env::var("EXEC_MODEL_FULL").is_ok()
}

#[test]
fn scope_protocol_invariants_hold_over_all_bounded_schedules() {
    let full = run_full();
    let mut ran = 0usize;
    for sc in scenarios() {
        if !full && sc.workers >= 3 {
            eprintln!(
                "exec_model: [{}] skipped in debug build (run with \
                 --release or EXEC_MODEL_FULL=1)",
                sc.name
            );
            continue;
        }
        let stats = check_scenario(&sc, DEFAULT_MAX_STATES)
            .unwrap_or_else(|e| panic!("model check failed: {e}"));
        eprintln!(
            "exec_model: [{}] ok — {} states, {} transitions \
             ({} workers, {} tasks)",
            sc.name,
            stats.states,
            stats.transitions,
            sc.workers,
            sc.tasks.len()
        );
        ran += 1;
    }
    assert!(ran >= 3, "scenario list shrank unexpectedly");
}

#[test]
fn scenario_list_covers_the_acceptance_bound() {
    // ≥ 3 workers and ≥ 4 tasks must be covered by at least one
    // scenario, and the panic/abandonment and spawn-from-task shapes
    // must stay represented — deleting a scenario may not silently
    // narrow the checked envelope.
    let all = scenarios();
    assert!(all
        .iter()
        .any(|s| s.workers >= 3 && s.tasks.len() >= 4));
    assert!(all
        .iter()
        .any(|s| s.tasks.iter().any(|t| t.panics)));
    assert!(all
        .iter()
        .any(|s| s.tasks.iter().any(|t| !t.spawns.is_empty())));
}
