//! Degradation properties at the quorum boundary, under the seeded
//! shrinker ([`sparsesecagg::testutil::prop_shrink`]).
//!
//! A scenario impairs three disjoint user classes through the network
//! simulator:
//!
//! * **lost uploads** (uplink loss = 1.0) — pure dropouts;
//! * **silent-after-upload** (uplink dies after its first frame) — the
//!   masked input lands, the unmask response never does: the class
//!   that actually exercises Shamir reconstruction-from-peers;
//! * **stragglers** (uplink latency 100× the Collecting deadline) —
//!   late uploads rejected as phase-confused.
//!
//! Property: while the responder count stays at or above the Shamir
//! quorum t+1, the round completes **bit-exactly** equal to the raw-bus
//! reference whose dropout set is {lost ∪ stragglers} (silent users'
//! inputs are *included* — their masks are reconstructed from peers).
//! One more silent user past the boundary and the round must fail with
//! a clean typed error — never a panic, never a wrong aggregate. A
//! failing draw shrinks to a minimal reproduction.

use sparsesecagg::coordinator::{Coordinator, PhaseDeadlines};
use sparsesecagg::exec::ExecMode;
use sparsesecagg::netsim::{LinkProfile, NetSim, NetSimConfig};
use sparsesecagg::prg::ChaCha20Rng;
use sparsesecagg::protocol::Params;
use sparsesecagg::testutil::prop_shrink;

#[derive(Clone, Debug)]
struct DegradationCase {
    n: usize,
    d: usize,
    alpha: f64,
    seed: u64,
    lost_uploads: usize,
    silent_after_upload: usize,
    stragglers: usize,
}

impl DegradationCase {
    fn quorum(&self) -> usize {
        self.n / 2 + 1 // t+1, t = ⌊n/2⌋
    }

    fn impaired(&self) -> usize {
        self.lost_uploads + self.silent_after_upload + self.stragglers
    }

    /// Quorum-preserving (the property's precondition), with at least
    /// one never-uploader so reconstruction is always on the path.
    fn feasible(&self) -> bool {
        self.n >= 8
            && self.d >= 64
            && self.lost_uploads + self.stragglers >= 1
            && self.n - self.impaired() >= self.quorum()
    }

    /// Impaired ids from the tail, one contiguous block per class:
    /// [silent | lost | stragglers] ending at n.
    fn straggler_ids(&self) -> Vec<usize> {
        (self.n - self.stragglers..self.n).collect()
    }
    fn lost_ids(&self) -> Vec<usize> {
        let hi = self.n - self.stragglers;
        (hi - self.lost_uploads..hi).collect()
    }
    fn silent_ids(&self) -> Vec<usize> {
        let hi = self.n - self.stragglers - self.lost_uploads;
        (hi - self.silent_after_upload..hi).collect()
    }
}

const COLLECT_DEADLINE_S: f64 = 0.1;

fn impaired_coordinator(c: &DegradationCase, p: Params) -> Coordinator {
    let brisk = LinkProfile {
        latency_s: 1e-3,
        ..LinkProfile::ideal()
    };
    let mut cfg = NetSimConfig::uniform(c.seed ^ 0xde6, brisk);
    for id in c.lost_ids() {
        cfg.overrides.push((id, LinkProfile { loss: 1.0, ..brisk }));
    }
    for id in c.silent_ids() {
        cfg.overrides
            .push((id, LinkProfile { die_after: Some(1), ..brisk }));
    }
    for id in c.straggler_ids() {
        cfg.overrides.push((
            id,
            LinkProfile { latency_s: 100.0 * COLLECT_DEADLINE_S, ..brisk },
        ));
    }
    let bus = Box::new(NetSim::over_bus(p.n, cfg));
    let mut coord = Coordinator::new_sparse_on(p, c.seed, bus);
    coord.exec_mode = ExecMode::Stealing;
    coord.shard_size = 64;
    coord.threads = 2;
    coord.deadlines = Some(PhaseDeadlines {
        collecting_s: COLLECT_DEADLINE_S,
        unmasking_s: f64::INFINITY,
    });
    coord
}

fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = ChaCha20Rng::from_seed_u64(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.next_f32() - 0.5).collect())
        .collect()
}

/// The property body (also reused by the explicit boundary test).
fn check(c: &DegradationCase) {
    assert!(c.feasible(), "generator/shrinker bug: {c:?}");
    let p = Params {
        n: c.n,
        d: c.d,
        alpha: c.alpha,
        theta: 0.0,
        c: 1024.0,
    };
    let ys = grads(c.n, c.d, c.seed ^ 0x99);
    let betas = vec![1.0 / c.n as f64; c.n];

    // --- at or above quorum: bit-exact completion.
    let mut coord = impaired_coordinator(c, p);
    let (got, ledger) = coord
        .run_round(0, &ys, &betas, &[])
        .unwrap_or_else(|e| {
            panic!("{c:?}: quorum-preserving impairment must complete \
                    ({} responders >= {}): {e:#}",
                   c.n - c.impaired(), c.quorum())
        });
    assert_eq!(ledger.rejected_frames, c.stragglers,
               "{c:?}: exactly the late uploads are rejected");
    assert!(ledger.excluded_users.is_empty(),
            "{c:?}: impairment is not equivocation");

    // Reference: lost + straggler users simply dropped; silent users
    // stay active — their inputs are in the sum, their masks come back
    // via peers' shares (Shamir exactness makes the response subset
    // immaterial).
    let mut ref_dropped = c.lost_ids();
    ref_dropped.extend(c.straggler_ids());
    ref_dropped.sort_unstable();
    let mut reference = Coordinator::new_sparse(p, c.seed);
    reference.exec_mode = ExecMode::Stealing;
    reference.shard_size = 64;
    reference.threads = 2;
    let (want, _) = reference
        .run_round(0, &ys, &betas, &ref_dropped)
        .expect("reference round");
    assert_eq!(got, want, "{c:?}: degraded aggregate differs from the \
                           dropout-equivalent reference");

    // --- one past the boundary: silence one more (honest) uploader so
    // the responder count lands at exactly t — a clean typed error.
    let mut twin = c.clone();
    twin.silent_after_upload =
        twin.n - twin.lost_uploads - twin.stragglers - twin.quorum() + 1;
    assert!(twin.silent_ids().iter().all(|&id| id < twin.n),
            "twin construction bug: {twin:?}");
    let mut sub = impaired_coordinator(&twin, p);
    let err = sub.run_round(0, &ys, &betas, &[]);
    assert!(err.is_err(),
            "{twin:?}: one responder below quorum must fail cleanly, \
             got Ok");
}

#[test]
fn quorum_boundary_property_with_shrinking() {
    prop_shrink(
        6,
        |rng| {
            let n = 8 + (rng.next_u32() % 9) as usize; // 8..=16
            let margin = n - (n / 2 + 1);
            let stragglers = (rng.next_u32() as usize) % (margin + 1);
            let lost =
                (rng.next_u32() as usize) % (margin - stragglers + 1);
            let silent = (rng.next_u32() as usize)
                % (margin - stragglers - lost + 1);
            let mut c = DegradationCase {
                n,
                d: 256 + (rng.next_u32() % 256) as usize,
                alpha: 0.2 + 0.3 * rng.next_f32() as f64,
                seed: 0xca5e ^ (rng.next_u32() as u64),
                lost_uploads: lost,
                silent_after_upload: silent,
                stragglers,
            };
            if c.lost_uploads + c.stragglers == 0 {
                // Keep reconstruction on the path (margin >= 3 for
                // n >= 8); make room if silent users filled the margin.
                c.silent_after_upload =
                    c.silent_after_upload.min(margin - 1);
                c.lost_uploads = 1;
            }
            c
        },
        |c| {
            // Halve the cohort, shed one impaired user per class,
            // halve d; infeasible candidates are filtered out.
            let mut cands =
                vec![DegradationCase { n: c.n / 2, ..c.clone() },
                     DegradationCase { d: c.d / 2, ..c.clone() }];
            if c.lost_uploads > 0 {
                cands.push(DegradationCase {
                    lost_uploads: c.lost_uploads - 1,
                    ..c.clone()
                });
            }
            if c.silent_after_upload > 0 {
                cands.push(DegradationCase {
                    silent_after_upload: c.silent_after_upload - 1,
                    ..c.clone()
                });
            }
            if c.stragglers > 0 {
                cands.push(DegradationCase {
                    stragglers: c.stragglers - 1,
                    ..c.clone()
                });
            }
            cands.retain(|x| x.feasible());
            cands
        },
        check,
    );
}

/// The boundary, pinned explicitly: n = 8 (quorum 5) with one user of
/// each impairment class completes at exactly quorum responders; the
/// sub-quorum twin inside `check` fails cleanly.
#[test]
fn quorum_boundary_exact_at_n8() {
    check(&DegradationCase {
        n: 8,
        d: 200,
        alpha: 0.3,
        seed: 0xb0da7,
        lost_uploads: 1,
        silent_after_upload: 1,
        stragglers: 1,
    });
}
