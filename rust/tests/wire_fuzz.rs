//! Wire-codec fuzzing and round-trip identity, over every frame type in
//! `protocol/messages.rs` that has a codec (`ModelBroadcast` is
//! accounting-only — it carries no payload to encode). Three layers:
//!
//! 1. encode∘decode identity on randomized well-formed messages;
//! 2. seeded pure-random byte buffers through every decoder — must
//!    return an error or a value, never panic or blow up allocation;
//! 3. random buffers behind a *valid* header (correct tag + patched
//!    length), which drive the payload parsers much deeper than layer 2.

//! The same three layers cover the durable round journal's record
//! codec ([`sparsesecagg::journal`]): framed encode∘decode identity
//! per record kind, seeded random-byte and valid-header/garbage
//! streams through `decode_stream` (no panics, hostile counts rejected
//! before allocation), and the corrupt-tail truncation property (any
//! cut of a valid stream recovers exactly a valid record prefix).

use sparsesecagg::journal::{self, Record};
use sparsesecagg::prg::ChaCha20Rng;
use sparsesecagg::protocol::messages::*;
use sparsesecagg::protocol::wire;
use sparsesecagg::shamir::Share;
use sparsesecagg::testutil::prop;

fn rand_share(rng: &mut ChaCha20Rng) -> Share {
    let mut y = [0u32; 8];
    for v in y.iter_mut() {
        *v = rng.next_field();
    }
    Share { x: 1 + rng.next_u32() % 255, y }
}

#[test]
fn encode_decode_identity_all_message_types() {
    prop(50, |rng| {
        let n = 2 + (rng.next_u32() as usize % 30);

        let ad = AdvertiseKeys {
            id: rng.next_u32() as usize % n,
            public: rng.next_u64(),
        };
        let got = wire::decode_advertise(&wire::encode_advertise(&ad)).unwrap();
        assert_eq!((got.id, got.public), (ad.id, ad.public));

        let roster = Roster {
            publics: (0..n).map(|_| rng.next_u64()).collect(),
        };
        let got = wire::decode_roster(&wire::encode_roster(&roster)).unwrap();
        assert_eq!(got.publics, roster.publics);

        let bundle = ShareBundle {
            owner: rng.next_u32() as usize % n,
            dest: rng.next_u32() as usize % n,
            dh_share: rand_share(rng),
            seed_share: rand_share(rng),
        };
        let got = wire::decode_share_bundle(
            &wire::encode_share_bundle(&bundle)).unwrap();
        assert_eq!(got.owner, bundle.owner);
        assert_eq!(got.dest, bundle.dest);
        assert_eq!(got.dh_share, bundle.dh_share);
        assert_eq!(got.seed_share, bundle.seed_share);

        let d = 16 + (rng.next_u32() as usize % 2000);
        let indices: Vec<u32> =
            (0..d as u32).filter(|_| rng.next_f32() < 0.15).collect();
        let sparse = SparseMaskedUpload {
            id: rng.next_u32() as usize % n,
            values: indices.iter().map(|_| rng.next_field()).collect(),
            indices,
            d,
        };
        let buf = wire::encode_sparse_upload(&sparse);
        assert_eq!(buf.len(), sparse.wire_bytes());
        let got = wire::decode_sparse_upload(&buf).unwrap();
        assert_eq!(got.indices, sparse.indices);
        assert_eq!(got.values, sparse.values);
        assert_eq!(got.d, sparse.d);

        let dense = DenseMaskedUpload {
            id: rng.next_u32() as usize % n,
            values: (0..1 + rng.next_u32() as usize % 500)
                .map(|_| rng.next_field())
                .collect(),
        };
        let buf = wire::encode_dense_upload(&dense);
        assert_eq!(buf.len(), dense.wire_bytes());
        let got = wire::decode_dense_upload(&buf).unwrap();
        assert_eq!(got.values, dense.values);

        let req = UnmaskRequest {
            dropped: (0..rng.next_u32() as usize % 6).collect(),
            survivors: (0..1 + rng.next_u32() as usize % 12).collect(),
        };
        let buf = wire::encode_unmask_request(&req);
        assert_eq!(buf.len(), req.wire_bytes());
        let got = wire::decode_unmask_request(&buf).unwrap();
        assert_eq!(got.dropped, req.dropped);
        assert_eq!(got.survivors, req.survivors);

        let resp = UnmaskResponse {
            id: rng.next_u32() as usize % n,
            dh_shares: (0..rng.next_u32() as usize % 5)
                .map(|o| (o, rand_share(rng)))
                .collect(),
            seed_shares: (0..rng.next_u32() as usize % 5)
                .map(|o| (o, rand_share(rng)))
                .collect(),
        };
        let buf = wire::encode_unmask_response(&resp);
        assert_eq!(buf.len(), resp.wire_bytes());
        let got = wire::decode_unmask_response(&buf).unwrap();
        assert_eq!(got.id, resp.id);
        assert_eq!(got.dh_shares, resp.dh_shares);
        assert_eq!(got.seed_shares, resp.seed_shares);

        let ga = GroupAggregate {
            group: rng.next_u32() as usize % 64,
            values: (0..rng.next_u32() as usize % 400)
                .map(|_| rng.next_u32())
                .collect(),
        };
        let buf = wire::encode_group_aggregate(&ga);
        assert_eq!(buf.len(), ga.wire_bytes());
        let got = wire::decode_group_aggregate(&buf).unwrap();
        assert_eq!(got.group, ga.group);
        assert_eq!(got.values, ga.values);

        let join = Join {
            id: rng.next_u32() as usize % n,
            cohort: rng.next_u32() % 16,
        };
        let buf = wire::encode_join(&join);
        assert_eq!(buf.len(), join.wire_bytes());
        assert_eq!(wire::decode_join(&buf).unwrap(), join);

        let hb = Heartbeat {
            id: rng.next_u32() as usize % n,
            seq: rng.next_u64(),
        };
        let buf = wire::encode_heartbeat(&hb);
        assert_eq!(buf.len(), hb.wire_bytes());
        assert_eq!(wire::decode_heartbeat(&buf).unwrap(), hb);

        let leave = Leave {
            id: rng.next_u32() as usize % n,
            cohort: rng.next_u32() % 16,
        };
        let buf = wire::encode_leave(&leave);
        assert_eq!(buf.len(), leave.wire_bytes());
        assert_eq!(wire::decode_leave(&buf).unwrap(), leave);
    });
}

fn run_all_decoders(buf: &[u8]) {
    let _ = wire::peek_header(buf);
    let _ = wire::decode_advertise(buf);
    let _ = wire::decode_roster(buf);
    let _ = wire::decode_share_bundle(buf);
    let _ = wire::decode_sparse_upload(buf);
    let _ = wire::decode_dense_upload(buf);
    let _ = wire::decode_unmask_request(buf);
    let _ = wire::decode_unmask_response(buf);
    let _ = wire::decode_group_aggregate(buf);
    let _ = wire::decode_heartbeat(buf);
    let _ = wire::decode_join(buf);
    let _ = wire::decode_leave(buf);
}

#[test]
fn random_bytes_never_panic_any_decoder() {
    let mut rng = ChaCha20Rng::from_seed_u64(0xfa22);
    for _ in 0..2000 {
        let len = (rng.next_u32() as usize) % 600;
        let buf: Vec<u8> =
            (0..len).map(|_| rng.next_u32() as u8).collect();
        run_all_decoders(&buf);
    }
}

#[test]
fn valid_header_garbage_payload_never_panics() {
    let mut rng = ChaCha20Rng::from_seed_u64(0xfa23);
    for round in 0..3000 {
        let tag = 1 + round % 12; // includes one invalid tag value (12)
        let len = (rng.next_u32() as usize) % 300;
        let mut buf = Vec::with_capacity(12 + len);
        buf.extend_from_slice(&(rng.next_u32() % 64).to_le_bytes());
        buf.extend_from_slice(&(tag as u32).to_le_bytes());
        buf.extend_from_slice(&(len as u32).to_le_bytes());
        for _ in 0..len {
            buf.push(rng.next_u32() as u8);
        }
        run_all_decoders(&buf);
    }
}

/// Hostile count fields must error out, not allocate gigabytes: a dense
/// upload whose header claims 2^32−1 values in a 20-byte payload.
#[test]
fn hostile_counts_rejected_without_allocation() {
    for tag in [5u32, 6, 7, 8] {
        let mut buf = Vec::new();
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&tag.to_le_bytes());
        buf.extend_from_slice(&20u32.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        assert!(wire::decode_dense_upload(&buf).is_err());
        assert!(wire::decode_unmask_request(&buf).is_err());
        assert!(wire::decode_unmask_response(&buf).is_err());
        assert!(wire::decode_group_aggregate(&buf).is_err());
    }
}

/// Strict-decode for the fixed-size service frames: truncation at every
/// byte, trailing bytes, and count-field garbage (there is no count —
/// any extra word must be rejected as trailing, never read as one).
#[test]
fn service_frames_strict_decode() {
    let j = wire::encode_join(&Join { id: 4, cohort: 1 });
    let h = wire::encode_heartbeat(&Heartbeat { id: 4, seq: 99 });
    let l = wire::encode_leave(&Leave { id: 4, cohort: 1 });
    for buf in [&j, &h, &l] {
        for cut in 0..buf.len() {
            let mut short = buf[..cut].to_vec();
            if short.len() >= 12 {
                repatch_len(&mut short);
            }
            assert!(wire::decode_join(&short).is_err());
            assert!(wire::decode_heartbeat(&short).is_err());
            assert!(wire::decode_leave(&short).is_err());
        }
        let mut long = buf.to_vec();
        long.extend_from_slice(&u32::MAX.to_le_bytes());
        repatch_len(&mut long);
        assert!(wire::decode_join(&long).is_err());
        assert!(wire::decode_heartbeat(&long).is_err());
        assert!(wire::decode_leave(&long).is_err());
    }
    // Join/Leave payloads alias byte-for-byte; the tag must decide.
    assert!(wire::decode_leave(&j).is_err());
    assert!(wire::decode_join(&l).is_err());
}

/// Re-patch a frame's header length field after mutating its payload
/// size, keeping header/buffer bookkeeping consistent so the *payload*
/// checks are what gets exercised.
fn repatch_len(buf: &mut Vec<u8>) {
    let len = (buf.len() - 12) as u32;
    buf[8..12].copy_from_slice(&len.to_le_bytes());
}

/// Strict-decode: a roster body that is not a whole number of 64-bit
/// keys must be rejected for every ragged tail length, not floored.
#[test]
fn roster_rejects_every_ragged_tail() {
    let m = Roster { publics: vec![7, 8, 9, 10] };
    for extra in 1..8usize {
        let mut buf = wire::encode_roster(&m);
        buf.extend(std::iter::repeat(0x5a).take(extra));
        repatch_len(&mut buf);
        assert!(wire::decode_roster(&buf).is_err(),
                "{extra} ragged bytes accepted");
    }
}

/// Strict-decode: the sparse values region is bounded by the bitmap's
/// popcount *before* it is read — a lying payload cannot zip-truncate
/// or smuggle trailing bytes, and padding bits cannot inflate the
/// popcount.
#[test]
fn sparse_upload_strict_region_checks() {
    prop(40, |prng| {
        let d = 9 + (prng.next_u32() as usize % 300);
        let indices: Vec<u32> =
            (0..d as u32).filter(|_| prng.next_f32() < 0.2).collect();
        let m = SparseMaskedUpload {
            id: prng.next_u32() as usize % 30,
            values: indices.iter().map(|_| prng.next_field()).collect(),
            indices,
            d,
        };
        let good = wire::encode_sparse_upload(&m);
        assert!(wire::decode_sparse_upload(&good).is_ok());
        if !m.values.is_empty() {
            // Drop one value: popcount now exceeds the region.
            let mut short = good[..good.len() - 4].to_vec();
            repatch_len(&mut short);
            assert!(wire::decode_sparse_upload(&short).is_err());
        }
        // Append one value: region now exceeds the popcount.
        let mut long = good.clone();
        long.extend_from_slice(&3u32.to_le_bytes());
        repatch_len(&mut long);
        assert!(wire::decode_sparse_upload(&long).is_err());
        // Set a padding bit beyond d (when d is not byte-aligned).
        if d % 8 != 0 {
            let mut padded = good.clone();
            let last_bitmap_byte = 12 + 4 + d / 8;
            padded[last_bitmap_byte] |= 1 << 7;
            assert!(wire::decode_sparse_upload(&padded).is_err(),
                    "padding bit accepted at d={d}");
        }
    });
    // Popcount-derived allocation stays bounded for a hostile d with a
    // consistent-looking but short payload.
    let mut buf = Vec::new();
    buf.extend_from_slice(&2u32.to_le_bytes()); // sender
    buf.extend_from_slice(&4u32.to_le_bytes()); // tag: sparse upload
    buf.extend_from_slice(&8u32.to_le_bytes()); // payload len 8
    buf.extend_from_slice(&(1u32 << 30).to_le_bytes()); // d = 2^30
    buf.extend_from_slice(&[0xff; 4]);
    assert!(wire::decode_sparse_upload(&buf).is_err());
}

// ---------------------------------------------------------------------
// Journal record codec (`sparsesecagg::journal`)
// ---------------------------------------------------------------------

fn rand_bytes(rng: &mut ChaCha20Rng, max: usize) -> Vec<u8> {
    (0..rng.next_u32() as usize % max)
        .map(|_| rng.next_u32() as u8)
        .collect()
}

fn rand_u32s(rng: &mut ChaCha20Rng, max: usize) -> Vec<u32> {
    (0..rng.next_u32() as usize % max)
        .map(|_| rng.next_u32())
        .collect()
}

/// One randomized record of each kind per draw, covering every field
/// shape the codec frames (floats round-trip by bit pattern).
fn rand_record(rng: &mut ChaCha20Rng) -> Record {
    match rng.next_u32() % 11 {
        0 => Record::Meta {
            kind: (rng.next_u32() % 2) as u8,
            n: rng.next_u32() % 1000,
            d: rng.next_u32(),
            alpha: rng.next_f32() as f64,
            theta: rng.next_f32() as f64,
            c: rng.next_f32(),
            entropy: rng.next_u64(),
        },
        1 => Record::SetupComplete {
            roster: (0..rng.next_u32() as usize % 32)
                .map(|_| rng.next_u64())
                .collect(),
        },
        2 => Record::RoundStart { round: rng.next_u32() },
        3 => Record::Upload {
            from: rng.next_u32() % 64,
            frame: rand_bytes(rng, 200),
        },
        4 => Record::UploadsClosed {
            upload_bytes: (0..rng.next_u32() as usize % 32)
                .map(|_| rng.next_u64())
                .collect(),
        },
        5 => Record::WaveSolicited { survivors: rand_u32s(rng, 32) },
        6 => Record::Response {
            from: rng.next_u32() % 64,
            frame: rand_bytes(rng, 200),
        },
        7 => Record::WaveClosed {
            recipients: rand_u32s(rng, 32),
            down_per_recipient: rand_u32s(rng, 32),
            sizes: rand_u32s(rng, 32),
        },
        8 => Record::Excluded { users: rand_u32s(rng, 8) },
        9 => Record::RoundComplete { round: rng.next_u32() },
        _ => Record::Snapshot { through_round: rng.next_u32() },
    }
}

/// encode∘decode identity, both per-payload and through the framed
/// stream scanner: a random multi-record stream decodes back to
/// exactly itself with a clean end-of-stream.
#[test]
fn journal_record_encode_decode_identity() {
    prop(50, |rng| {
        let recs: Vec<Record> =
            (0..1 + rng.next_u32() as usize % 12)
                .map(|_| rand_record(rng))
                .collect();
        let mut stream = Vec::new();
        for r in &recs {
            assert_eq!(&Record::decode(&r.encode()).unwrap(), r);
            stream.extend_from_slice(&journal::frame_record(r));
        }
        let (got, end, err) = journal::decode_stream(&stream);
        assert!(err.is_none(), "{err:?}");
        assert_eq!(end, stream.len());
        assert_eq!(got, recs);
    });
}

/// Seeded pure-random byte streams: the scanner must return (treating
/// anything implausible as a torn tail), never panic, and never report
/// more valid bytes than it was given.
#[test]
fn journal_random_byte_streams_never_panic() {
    let mut rng = ChaCha20Rng::from_seed_u64(0x10a7);
    for _ in 0..2000 {
        let buf = rand_bytes(&mut rng, 600);
        let (recs, end, _err) = journal::decode_stream(&buf);
        assert!(end <= buf.len());
        assert!(recs.len() <= buf.len() / 8 + 1);
        let _ = Record::decode(&buf);
    }
}

/// A *CRC-valid* frame over a garbage payload drives the payload
/// parser itself: the scan either yields a legitimately-decodable
/// record or stops with the typed corruption error (tampering, not
/// tearing) — never a panic.
#[test]
fn journal_valid_header_garbage_payload_is_typed() {
    let mut rng = ChaCha20Rng::from_seed_u64(0x10a8);
    for _ in 0..2000 {
        let payload = rand_bytes(&mut rng, 120);
        let mut buf = Vec::with_capacity(8 + payload.len());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&journal::crc32(&payload).to_le_bytes());
        buf.extend_from_slice(&payload);
        let (recs, end, err) = journal::decode_stream(&buf);
        match err {
            Some(e) => {
                assert!(recs.is_empty() && end == 0,
                        "corruption after progress: {e}");
            }
            None => {
                assert_eq!((recs.len(), end), (1, buf.len()),
                           "CRC-valid frame neither decoded nor \
                            reported corrupt");
            }
        }
    }
}

/// Hostile vector counts behind a correct CRC must be rejected before
/// allocation: a `SetupComplete` claiming 2^32−1 roster keys in a
/// 5-byte payload is typed corruption, not a 32 GiB allocation.
#[test]
fn journal_hostile_counts_rejected_without_allocation() {
    let mut payload = vec![2u8]; // kind: SetupComplete
    payload.extend_from_slice(&u32::MAX.to_le_bytes());
    let mut buf = Vec::new();
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&journal::crc32(&payload).to_le_bytes());
    buf.extend_from_slice(&payload);
    let (recs, end, err) = journal::decode_stream(&buf);
    assert!(recs.is_empty() && end == 0);
    assert!(err.is_some(), "hostile count must be typed corruption");
    // A length prefix past the record cap is a torn tail, not an
    // allocation request.
    let huge = (1u32 << 29).to_le_bytes();
    let mut buf = huge.to_vec();
    buf.extend_from_slice(&[0u8; 12]);
    let (recs, end, err) = journal::decode_stream(&buf);
    assert!(recs.is_empty() && end == 0 && err.is_none());
}

/// Corrupt-tail truncation property: cutting a valid stream at ANY
/// byte recovers exactly a prefix of its records — no invented
/// records, no corruption error, and the valid-end watermark lands on
/// the frame boundary of the last surviving record.
#[test]
fn journal_any_truncation_recovers_exact_record_prefix() {
    let mut rng = ChaCha20Rng::from_seed_u64(0x10a9);
    let recs: Vec<Record> = (0..6).map(|_| rand_record(&mut rng)).collect();
    let mut stream = Vec::new();
    let mut boundaries = vec![0usize];
    for r in &recs {
        stream.extend_from_slice(&journal::frame_record(r));
        boundaries.push(stream.len());
    }
    for cut in 0..=stream.len() {
        let (got, end, err) = journal::decode_stream(&stream[..cut]);
        assert!(err.is_none(), "cut {cut}: {err:?}");
        let keep = boundaries.iter().filter(|&&b| b <= cut).count() - 1;
        assert_eq!(end, boundaries[keep], "cut {cut}");
        assert_eq!(got, recs[..keep], "cut {cut}");
    }
}
