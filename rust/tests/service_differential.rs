//! Socket-vs-bus differential: rounds driven over the real localhost
//! TCP star ([`sparsesecagg::transport::tcp::TcpBus`]) must be
//! indistinguishable from the deterministic in-memory reference bus —
//! bit-exact aggregate and identical per-user byte ledgers — across
//! both protocols. This is the proof that the [`Transport`] trait seam
//! really is the deployment seam: swapping kernel sockets for the
//! in-memory queues changes *nothing* the protocol can observe.
//!
//! Cross-sender interleaving at the server differs between the two
//! buses (TCP only preserves per-connection FIFO); the ingest layer
//! keys state per sender, so every pinned observable is insensitive to
//! it by construction — which is exactly what these tests pin.

use sparsesecagg::coordinator::Coordinator;
use sparsesecagg::network::draw_dropouts;
use sparsesecagg::prg::ChaCha20Rng;
use sparsesecagg::protocol::Params;
use sparsesecagg::transport::tcp::TcpBus;

fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = ChaCha20Rng::from_seed_u64(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.next_f32() - 0.5).collect())
        .collect()
}

/// Two rounds (with drawn dropouts) over real sockets vs the raw bus:
/// aggregate and per-user byte ledgers must match bit-exactly, and the
/// validating ingest must reject nothing (well-formed traffic only).
fn assert_socket_rounds_bit_exact(secagg: bool) {
    let alpha = if secagg { 1.0 } else { 0.3 };
    let p = Params { n: 8, d: 400, alpha, theta: 0.2, c: 1024.0 };
    let ys = grads(p.n, p.d, 0x7c9);
    let betas = vec![1.0 / p.n as f64; p.n];

    let mut raw = if secagg {
        Coordinator::new_secagg(p, 42)
    } else {
        Coordinator::new_sparse(p, 42)
    };
    let bus = Box::new(TcpBus::connect_star(p.n).expect("tcp star"));
    let mut tcp = if secagg {
        Coordinator::new_secagg_on(p, 42, bus)
    } else {
        Coordinator::new_sparse_on(p, 42, bus)
    };

    for round in 0..2u32 {
        let dropped = draw_dropouts(p.n, p.theta, round, 0xd0, true);
        let (want, lw) = raw
            .run_round(round, &ys, &betas, &dropped)
            .expect("in-memory reference round");
        let (got, lg) = tcp
            .run_round(round, &ys, &betas, &dropped)
            .expect("tcp round");
        let tag = format!("secagg={secagg} round={round}");
        assert_eq!(got, want, "{tag}: aggregate differs over sockets");
        assert_eq!(lg.up_bytes, lw.up_bytes,
                   "{tag}: per-user upload ledger differs");
        assert_eq!(lg.down_bytes, lw.down_bytes,
                   "{tag}: per-user download ledger differs");
        assert_eq!(lg.rejected_frames, 0, "{tag}: spurious rejects");
        assert_eq!(lg.excluded_users, lw.excluded_users, "{tag}");
    }
}

#[test]
fn tcp_round_is_bit_exact_sparse() {
    assert_socket_rounds_bit_exact(false);
}

#[test]
fn tcp_round_is_bit_exact_secagg() {
    assert_socket_rounds_bit_exact(true);
}

/// A client connection severed before the round is *not* declared
/// dropped to the coordinator: its upload dies on the dead socket, the
/// server simply never receives it, and the absence degrades through
/// the standard dropout-recovery path — bit-exact against a reference
/// round where the same user was dropped up front. Never a stall,
/// never an exclusion. (Exactness holds regardless of cross-sender
/// arrival order because aggregation is modular field arithmetic.)
#[test]
fn severed_connection_degrades_to_dropout_bit_exact() {
    let p = Params { n: 8, d: 300, alpha: 0.3, theta: 0.0, c: 1024.0 };
    let ys = grads(p.n, p.d, 0x5e7);
    let betas = vec![1.0 / p.n as f64; p.n];
    let gone = 5usize;

    let mut reference = Coordinator::new_sparse(p, 9);
    let (want, _) = reference
        .run_round(0, &ys, &betas, &[gone])
        .expect("reference with user dropped");

    let mut bus = TcpBus::connect_star(p.n).expect("tcp star");
    bus.disconnect_client(gone);
    let mut tcp = Coordinator::new_sparse_on(p, 9, Box::new(bus));
    let (got, ledger) = tcp
        .run_round(0, &ys, &betas, &[])
        .expect("round must survive a severed connection");
    assert_eq!(got, want, "severed connection must equal a dropout");
    assert!(ledger.excluded_users.is_empty(),
            "disconnection is not equivocation");
    assert_eq!(ledger.retries, 0);
}
