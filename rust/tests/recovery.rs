//! Round-recovery and rate-limiting suite — the availability half of
//! the secure-aggregation story.
//!
//! * **Soak**: ≥ 20 consecutive byzantine rounds through the frame
//!   driver with a catalog injector *and* a two-faced share poisoner:
//!   zero lost rounds while the honest quorum holds, every round
//!   bit-exact to its honest-minus-excluded reference, deterministic
//!   under the seed (two full runs compared bit-for-bit).
//! * **Quorum starvation**: recovery that would dip below ⌊N/2⌋+1
//!   responders aborts with a clean error after the retry budget —
//!   never a panic, never a fabricated aggregate.
//! * **Rate limiter**: a seeded flood from one endpoint is shed before
//!   decode (`rate_limited_frames` counted exactly, round bit-exact vs
//!   the no-flood reference), per-sender budgets are isolated, and an
//!   honest sender at exactly the budget is never shed — the
//!   off-by-one is pinned from both sides (budget 2 completes, budget
//!   1 starves the response wave and fails cleanly).
//! * **Shrinker adoption**: the recovery property runs under
//!   `testutil::prop_shrink`, so a failure reports its minimal cohort.

use sparsesecagg::adversary::{Adversary, Attack, TwoFaced};
use sparsesecagg::coordinator::Coordinator;
use sparsesecagg::exec::ExecMode;
use sparsesecagg::field;
use sparsesecagg::fl::{run_fl, FlConfig, Trainer};
use sparsesecagg::netsim::{LinkProfile, NetSim, NetSimConfig};
use sparsesecagg::prg::ChaCha20Rng;
use sparsesecagg::protocol::{sparse, Params};
use sparsesecagg::testutil::prop_shrink;

fn params(n: usize, d: usize, alpha: f64, theta: f64) -> Params {
    Params { n, d, alpha, theta, c: 1024.0 }
}

fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = ChaCha20Rng::from_seed_u64(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.next_f32() - 0.5).collect())
        .collect()
}

fn coordinator(p: Params, entropy: u64) -> Coordinator {
    let mut c = Coordinator::new_sparse(p, entropy);
    c.exec_mode = ExecMode::Stealing;
    c.shard_size = 64;
    c.threads = 3;
    c
}

/// One full soak run: 24 rounds, byzantine ids {0, 1} (0 injects the
/// frame catalog, 1 two-faced value-poisons every round), rotating
/// dropout patterns that keep the response set inside the
/// unique-decoding radius (≥ t+1+2 = 9 responders of N = 12). Returns
/// the per-round aggregates for determinism comparison.
fn soak_run(entropy: u64) -> Vec<Vec<f32>> {
    let p = params(12, 250, 0.35, 0.0);
    let ys = grads(p.n, p.d, 0x50a6_u64 ^ entropy);
    let betas = vec![1.0 / p.n as f64; p.n];
    let dropout_patterns: [&[usize]; 3] = [&[], &[5], &[5, 9]];

    let mut attacked = coordinator(p, entropy);
    let mut reference = coordinator(p, entropy);
    let mut adv = Adversary::new(0.2, entropy ^ 0xad);
    adv.two_faced = vec![(1, TwoFaced::PoisonValues)];

    let mut aggs = Vec::new();
    for round in 0..24u32 {
        let dropped = dropout_patterns[round as usize % 3].to_vec();
        let (got, ledger) = attacked
            .run_round_adversarial(round, &ys, &betas, &dropped, &mut adv)
            .unwrap_or_else(|e| {
                panic!("soak round {round} lost under byzantine \
                        pressure with honest quorum intact: {e:#}")
            });
        assert_eq!(ledger.excluded_users, vec![1], "round {round}");
        assert_eq!(ledger.retries, 1, "round {round}");
        assert!(ledger.rejected_frames > 0, "round {round}");

        let mut ref_dropped = dropped.clone();
        ref_dropped.extend([0usize, 1]);
        let (want, ref_ledger) = reference
            .run_round(round, &ys, &betas, &ref_dropped)
            .unwrap();
        assert_eq!(ref_ledger.retries, 0);
        assert_eq!(got, want,
                   "round {round}: recovered aggregate diverged from \
                    honest-minus-excluded reference");
        aggs.push(got);
    }
    aggs
}

/// ≥ 20 byzantine rounds, zero lost, bit-exact, deterministic.
#[test]
fn soak_byzantine_rounds_recover_without_loss_and_deterministically() {
    let a = soak_run(31);
    let b = soak_run(31);
    assert_eq!(a.len(), 24);
    for (r, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x, y, "soak round {r} not deterministic under seed");
    }
}

/// Quorum starvation: excluding the identified equivocator leaves
/// fewer than ⌊N/2⌋+1 responders — the retry must end in a clean
/// error, not a panic and not a wrong aggregate. (N = 8, t+1 = 5:
/// byzantine {0, 1} with 1 two-faced, honest dropouts {6, 7} → five
/// uploaders; excluding the equivocator leaves four.)
#[test]
fn quorum_starvation_fails_cleanly_after_retry() {
    let p = params(8, 200, 0.4, 0.0);
    let ys = grads(p.n, p.d, 0x57a2);
    let betas = vec![1.0 / p.n as f64; p.n];
    let mut c = coordinator(p, 91);
    let mut adv = Adversary::new(0.25, 5);
    adv.two_faced = vec![(1, TwoFaced::PoisonGeometry)];
    let res =
        c.run_round_adversarial(0, &ys, &betas, &[6, 7], &mut adv);
    assert!(res.is_err(),
            "post-exclusion quorum loss must be a clean error");
}

/// A seeded flood from one byzantine endpoint alongside its catalog
/// frame: the budget admits (and the ingest rejects) exactly
/// `rate_limit` frames from that sender; everything past the budget is
/// shed before decode; honest traffic is untouched and the round is
/// bit-exact to the no-flood reference.
#[test]
fn flood_from_one_sender_is_shed_and_round_bit_exact() {
    let p = params(10, 300, 0.3, 0.0);
    let ys = grads(p.n, p.d, 0xf10d);
    let betas = vec![1.0 / p.n as f64; p.n];

    let mut reference = coordinator(p, 44);
    let (want, _) = reference.run_round(0, &ys, &betas, &[0]).unwrap();

    let mut attacked = coordinator(p, 44);
    attacked.rate_limit = 4;
    let mut adv =
        Adversary::with_catalog(0.1, 7, &[Attack::GarbagePayload]);
    adv.flood = Some((0, 40));
    let (got, ledger) = attacked
        .run_round_adversarial(0, &ys, &betas, &[], &mut adv)
        .unwrap();
    // Endpoint 0 sends 42 frames: 1 catalog garbage + 40 flood in the
    // upload phase, 1 catalog fallback in the response phase. Budget 4
    // admits the first four (all garbage → rejected at decode); the
    // remaining 38 are shed before decode.
    assert_eq!(adv.flooded, 40);
    assert_eq!(adv.injected, 2);
    assert_eq!(ledger.rejected_frames, 4);
    assert_eq!(ledger.rate_limited_frames, 38);
    assert_eq!(got, want, "flooded round diverged from reference");
    assert_eq!(ledger.retries, 0);
}

/// Budget-exactness property over random flood sizes and budgets, with
/// the flood arriving from a *forged out-of-range endpoint*: sheds are
/// exactly `flood − budget` (overflow bucket), admitted frames are all
/// rejected at decode, honest senders are never shed, and the round
/// stays bit-exact.
#[test]
fn flood_shedding_is_exact_for_any_budget() {
    let p = params(8, 150, 0.4, 0.0);
    let ys = grads(p.n, p.d, 0xf11);
    let betas = vec![1.0 / p.n as f64; p.n];
    let mut reference = coordinator(p, 45);
    let (want, _) = reference.run_round(0, &ys, &betas, &[]).unwrap();
    for case in 0..8u64 {
        let mut rng = ChaCha20Rng::from_seed_u64(0xb0d6e7 + case);
        let budget = 2 + (rng.next_u32() as usize % 6); // 2..=7
        let flood = rng.next_u32() as usize % 50;
        let mut attacked = coordinator(p, 45);
        attacked.rate_limit = budget;
        // frac 0 ⇒ no byzantine users, no catalog frames — the flood
        // from forged endpoint n+3 is the only hostile traffic.
        let mut adv = Adversary::with_catalog(
            0.0, 3, &[Attack::GarbagePayload]);
        adv.flood = Some((p.n + 3, flood));
        let (got, ledger) = attacked
            .run_round_adversarial(0, &ys, &betas, &[], &mut adv)
            .unwrap();
        let admitted = flood.min(budget);
        assert_eq!(ledger.rejected_frames, admitted,
                   "budget {budget}, flood {flood}");
        assert_eq!(ledger.rate_limited_frames, flood - admitted,
                   "budget {budget}, flood {flood}");
        assert_eq!(got, want, "budget {budget}, flood {flood}");
    }
}

/// The honest boundary, pinned from both sides: an honest sender needs
/// exactly 2 frames per retry-free round (upload + response). At
/// budget 2 nothing is shed and the round is bit-exact to the
/// unlimited reference; at budget 1 every response wave is shed and
/// the round fails cleanly (response starvation), proving the limiter
/// admits frames 1..=budget, not budget−1.
#[test]
fn honest_sender_at_exact_budget_is_never_shed() {
    let p = params(8, 200, 0.4, 0.0);
    let ys = grads(p.n, p.d, 0xb0b);
    let betas = vec![1.0 / p.n as f64; p.n];
    let mut unlimited = coordinator(p, 46);
    let (want, _) = unlimited.run_round(0, &ys, &betas, &[]).unwrap();

    let mut at_budget = coordinator(p, 46);
    at_budget.rate_limit = 2;
    let (got, ledger) = at_budget.run_round(0, &ys, &betas, &[]).unwrap();
    assert_eq!(ledger.rate_limited_frames, 0,
               "honest sender at exactly the budget must not be shed");
    assert_eq!(got, want);

    let mut starved = coordinator(p, 46);
    starved.rate_limit = 1;
    assert!(starved.run_round(0, &ys, &betas, &[]).is_err(),
            "budget 1 sheds every unmask response: clean failure");
}

/// Rate limiting composes with recovery: with the budget sized for
/// honest retry-free traffic (2 frames) AND a two-faced equivocator
/// forcing a re-solicitation wave, the replenished budget lets every
/// honest retry response through — the round completes bit-exactly,
/// nothing honest is shed, and the exclusion is still accounted.
#[test]
fn recovery_completes_under_honest_sized_rate_limit() {
    let p = params(10, 250, 0.3, 0.0);
    let ys = grads(p.n, p.d, 0x2a7e);
    let betas = vec![1.0 / p.n as f64; p.n];

    let mut reference = coordinator(p, 47);
    let (want, _) = reference.run_round(0, &ys, &betas, &[0, 1]).unwrap();

    let mut attacked = coordinator(p, 47);
    attacked.rate_limit = 2; // honest upload + one response
    // Garbage-only catalog: the injector spends its *own* budget
    // (replay/spoof entries would bill the replayed frame to the honest
    // victim's endpoint and eat its budget — a different scenario).
    let mut adv =
        Adversary::with_catalog(0.2, 0x2a7f, &[Attack::GarbagePayload]);
    adv.two_faced = vec![(1, TwoFaced::PoisonValues)];
    let (got, ledger) = attacked
        .run_round_adversarial(0, &ys, &betas, &[], &mut adv)
        .expect("tight honest budget must not starve recovery");
    assert_eq!(got, want);
    assert_eq!(ledger.excluded_users, vec![1]);
    assert_eq!(ledger.retries, 1);
    assert_eq!(ledger.rate_limited_frames, 0,
               "honest retry responses must ride the replenished budget");
}

/// Recovery property under the minimal-failing-case shrinker: for any
/// cohort inside the unique-decoding radius (n ≥ t+3, i.e. n ≥ 6), a
/// single value-poisoning survivor is identified, excluded, and the
/// round finishes bit-exact to the reference without it. On failure
/// the shrinker reports the smallest (n, d) reproduction.
#[derive(Clone, Copy, Debug)]
struct RecoveryCase {
    n: usize,
    d: usize,
    alpha: f64,
    seed: u64,
}

fn shrink_recovery(c: &RecoveryCase) -> Vec<RecoveryCase> {
    let mut out = Vec::new();
    if c.n > 6 {
        out.push(RecoveryCase { n: (c.n / 2).max(6), ..*c }); // halve cohort
        out.push(RecoveryCase { n: c.n - 1, ..*c }); // drop one user
    }
    if c.d > 60 {
        out.push(RecoveryCase { d: c.d / 2, ..*c });
    }
    out
}

fn check_recovery(c: &RecoveryCase) {
    let p = params(c.n, c.d, c.alpha, 0.0);
    let ys = grads(p.n, p.d, c.seed);
    let beta = 1.0 / p.n as f64;

    let (r_users, mut r_server) = sparse::setup(p, c.seed ^ 0xc0);
    r_server.begin_round();
    let mut scratch = vec![0u32; p.d];
    for u in r_users.iter().skip(1) {
        let plan = u.mask_plan(0, &p, &mut scratch);
        r_server.receive_upload(
            u.masked_upload(0, &ys[u.id], beta, &p, plan));
    }
    r_server.close_uploads();
    let req = r_server.unmask_request();
    for u in r_users.iter().skip(1) {
        r_server.try_receive_response(u.respond_unmask(&req)).unwrap();
    }
    let responses = r_server.take_responses();
    r_server.finish_round(0, &responses).unwrap();
    let want = r_server.aggregate_field().to_vec();

    let (users, mut server) = sparse::setup(p, c.seed ^ 0xc0);
    server.begin_round();
    for u in &users {
        let plan = u.mask_plan(0, &p, &mut scratch);
        server.receive_upload(
            u.masked_upload(0, &ys[u.id], beta, &p, plan));
    }
    server.close_uploads();
    let req = server.unmask_request();
    for u in &users {
        let mut resp = u.respond_unmask(&req);
        if u.id == 0 {
            for (_, s) in resp.seed_shares.iter_mut() {
                s.y[2] = field::add(s.y[2], 7);
            }
        }
        server.try_receive_response(resp).unwrap();
    }
    let (_, outcome) = server
        .finish_round_with_recovery(0, 1, |req| {
            users.iter().filter(|u| u.id != 0)
                .map(|u| u.respond_unmask(req)).collect()
        })
        .unwrap_or_else(|e| panic!("{c:?}: must recover: {e}"));
    assert_eq!(outcome.excluded, vec![0], "{c:?}");
    assert_eq!(outcome.retries, 1, "{c:?}");
    assert_eq!(server.aggregate_field(), &want[..], "{c:?}");
}

#[test]
fn recovery_property_with_minimal_case_shrinking() {
    prop_shrink(
        10,
        |rng| RecoveryCase {
            n: 6 + (rng.next_u32() as usize % 8),
            d: 100 + (rng.next_u32() as usize % 300),
            alpha: 0.25 + 0.4 * rng.next_f32() as f64,
            seed: rng.next_u64(),
        },
        shrink_recovery,
        check_recovery,
    );
}

/// One churn-soak run over the impairment simulator: 30 rounds on
/// jittery, bandwidth-capped links with a seeded churn draw of 0..=3
/// leavers per round AND byzantine ids {0, 1} (0 silenced catalog
/// injector, 1 two-faced value-poisoner). Sizing keeps every round
/// recoverable by construction: N = 14, t+1 = 8, and the response set
/// stays at or above the unique-decoding radius t+1+2 = 10 even at
/// peak churn (14 − 3 leavers − 1 silenced = 10). Returns the
/// per-round aggregates for determinism comparison.
fn churn_soak_run(entropy: u64) -> Vec<Vec<f32>> {
    let p = params(14, 220, 0.35, 0.0);
    let ys = grads(p.n, p.d, 0xc4u64 ^ entropy);
    let betas = vec![1.0 / p.n as f64; p.n];
    let wan = LinkProfile {
        latency_s: 1e-3,
        jitter_s: 2e-3, // 2x the latency: reordering every phase
        bandwidth_bps: 50e6,
        loss: 0.0,
        die_after: None,
    };
    let bus = Box::new(NetSim::over_bus(
        p.n, NetSimConfig::uniform(entropy ^ 0x9e7, wan)));
    let mut attacked = Coordinator::new_sparse_on(p, entropy, bus);
    attacked.exec_mode = ExecMode::Stealing;
    attacked.shard_size = 64;
    attacked.threads = 3;
    let mut reference = coordinator(p, entropy);
    let mut adv = Adversary::new(2.0 / 14.0, entropy ^ 0xad);
    adv.two_faced = vec![(1, TwoFaced::PoisonValues)];

    let mut churn_rng = ChaCha20Rng::from_seed_u64(entropy ^ 0xc42);
    let mut aggs = Vec::new();
    for round in 0..30u32 {
        // Seeded churn: 0..=3 distinct leavers from the honest pool
        // {2, …, 13} join late / leave early this round.
        let k = churn_rng.next_u32() as usize % 4;
        let mut pool: Vec<usize> = (2..p.n).collect();
        let mut leave = Vec::new();
        for _ in 0..k {
            let i = churn_rng.next_u32() as usize % pool.len();
            leave.push(pool.swap_remove(i));
        }
        leave.sort_unstable();

        let (got, ledger) = attacked
            .run_round_adversarial(round, &ys, &betas, &leave, &mut adv)
            .unwrap_or_else(|e| {
                panic!("churn soak round {round} (leavers {leave:?}) \
                        lost while recoverable: {e:#}")
            });
        assert_eq!(ledger.excluded_users, vec![1], "round {round}");
        assert_eq!(ledger.retries, 1, "round {round}");
        assert!(ledger.rejected_frames > 0, "round {round}");

        let mut ref_dropped = leave.clone();
        ref_dropped.extend([0usize, 1]);
        ref_dropped.sort_unstable();
        let (want, _) = reference
            .run_round(round, &ys, &betas, &ref_dropped)
            .unwrap();
        assert_eq!(got, want,
                   "round {round}: churned aggregate diverged from \
                    honest-minus-excluded reference");
        aggs.push(got);
    }
    assert!(attacked.bus_clock_s() > 0.0,
            "the impairment layer must have cost simulated time");
    aggs
}

/// ≥ 30 rounds of churn + byzantine pressure over impaired links: zero
/// recoverable rounds lost, every round bit-exact to its reference,
/// and the full trajectory bit-deterministic under the seed.
#[test]
fn churn_soak_over_impaired_links_is_lossless_and_deterministic() {
    let a = churn_soak_run(77);
    let b = churn_soak_run(77);
    assert_eq!(a.len(), 30);
    for (r, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x, y,
                   "churn soak round {r} not deterministic under seed");
    }
}

/// `run_fl` soak under the `byzantine` config knob (requires `make
/// artifacts`; self-skips otherwise): ≥ 20 rounds, the last byzantine
/// id two-faced every round, recovery on — zero aborted rounds and a
/// bit-deterministic history under the seed. The quorum-starvation
/// side of the knob is covered hermetically above.
#[test]
fn run_fl_soak_under_byzantine_knob() {
    let t = match Trainer::load("artifacts", "mlp", false) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            return;
        }
    };
    let cfg = FlConfig {
        model: "mlp".into(),
        users: 12,
        rounds: 20,
        samples_per_user: 40,
        test_samples: 100,
        alpha: 0.3,
        theta: 0.0,
        lr: 0.05,
        byzantine: 0.2,
        eval_every: 5,
        ..FlConfig::default()
    };
    let a = run_fl(&cfg, &t).expect("no round may be lost to recovery");
    assert_eq!(a.history.len(), 20);
    let b = run_fl(&cfg, &t).unwrap();
    for (x, y) in a.history.iter().zip(&b.history) {
        assert_eq!(x.mean_local_loss.to_bits(), y.mean_local_loss.to_bits(),
                   "round {}: byzantine training not deterministic",
                   x.round);
        assert_eq!(x.max_up_bytes, y.max_up_bytes);
    }
}
