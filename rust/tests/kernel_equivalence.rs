//! Closes the three-implementation triangle for the fused
//! quantize→φ→mask→select hot path:
//!
//!   Pallas kernel (L1, python) ≡ pure-jnp ref (pytest) — checked in CI
//!   lowered HLO artifact (PJRT) ≡ Rust native path    — checked HERE
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use sparsesecagg::prg::{ChaCha20Rng, Seed};
use sparsesecagg::protocol::{sparse, Params};
use sparsesecagg::quantize;
use sparsesecagg::runtime::{Manifest, QuantMask, Runtime};
use std::path::Path;

fn artifacts() -> Option<Manifest> {
    let dir = Path::new("artifacts");
    match Manifest::load(dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn hlo_kernel_matches_rust_reference_bitexact() {
    let Some(manifest) = artifacts() else { return };
    let m = manifest.model("cnn_mnist_small").unwrap();
    let rt = Runtime::cpu().unwrap();
    let qm = QuantMask::load(&rt, m).unwrap();
    let dpad = m.dpad;

    let mut rng = ChaCha20Rng::from_seed_u64(2024);
    for case in 0..3 {
        let y: Vec<f32> =
            (0..dpad).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let rand: Vec<f32> = (0..dpad).map(|_| rng.next_f32()).collect();
        let masksum: Vec<u32> = (0..dpad).map(|_| rng.next_field()).collect();
        let select: Vec<u32> =
            (0..dpad).map(|_| (rng.next_f32() < 0.3) as u32).collect();
        let scale = 0.5 + case as f32;
        let c = 4096.0;

        let hlo = qm.run(&y, &rand, &masksum, &select, scale, c).unwrap();

        let select8: Vec<u8> = select.iter().map(|&v| v as u8).collect();
        let native = quantize::quantize_mask_select(&y, &rand, &masksum,
                                                    &select8, scale, c);
        assert_eq!(hlo, native, "HLO kernel diverged from native (case {case})");
    }
}

#[test]
fn protocol_upload_identical_through_hlo_and_native() {
    // End-to-end: a protocol user's MaskedInput must be bit-identical
    // whether computed natively or through the L1 artifact.
    let Some(manifest) = artifacts() else { return };
    let m = manifest.model("cnn_mnist_small").unwrap();
    let rt = Runtime::cpu().unwrap();
    let qm = QuantMask::load(&rt, m).unwrap();

    let params = Params { n: 6, d: m.d, alpha: 0.15, theta: 0.1, c: 1024.0 };
    let (users, _server) = sparse::setup(params, 33);
    let mut rng = ChaCha20Rng::from_seed_u64(9);
    let y: Vec<f32> =
        (0..m.d).map(|_| rng.next_f32() * 0.02 - 0.01).collect();
    let beta = 1.0 / 6.0;

    let mut scratch = vec![0u32; m.d];
    for u in users.iter().take(3) {
        let plan_native = u.mask_plan(4, &params, &mut scratch);
        let native = u.masked_upload(4, &y, beta, &params, plan_native);

        let plan_hlo = u.mask_plan(4, &params, &mut scratch);
        let (y_pad, rand, masksum, select) =
            u.kernel_inputs(4, &y, &params, &plan_hlo, m.dpad);
        let dense = qm
            .run(&y_pad, &rand, &masksum, &select,
                 params.scale(beta), params.c)
            .unwrap();
        let hlo = u.upload_from_kernel(plan_hlo, &dense, m.d);

        assert_eq!(native.indices, hlo.indices);
        assert_eq!(native.values, hlo.values,
                   "user {} upload differs between paths", u.id);
    }
}

#[test]
fn rounding_stream_is_deterministic_and_prefix_stable() {
    // The bit-equivalence above hinges on the compressed rounding stream
    // being identical between the sparse native path and the dense
    // scatter: deterministic per (seed, round) and prefix-stable in count.
    let seed = Seed([3, 1, 4, 1, 5, 9, 2, 6]);
    let a = sparsesecagg::masking::rounding_values(seed, 7, 1000);
    let b = sparsesecagg::masking::rounding_values(seed, 7, 1000);
    assert_eq!(a, b);
    let prefix = sparsesecagg::masking::rounding_values(seed, 7, 100);
    assert_eq!(&a[..100], &prefix[..]);
    let other_round = sparsesecagg::masking::rounding_values(seed, 8, 100);
    assert_ne!(&a[..100], &other_round[..]);
}
