//! Differential suite for the network-impairment simulator
//! ([`sparsesecagg::netsim`]).
//!
//! * **Zero-impairment differential**: a round driven over `NetSim`
//!   with ideal links is *indistinguishable* from the raw
//!   [`InMemoryBus`] — bit-exact aggregate, identical per-user byte
//!   ledgers, identical simulated comm clock (`to_bits`), identical
//!   scheduling counters, zero rejected frames, and a virtual clock
//!   that never advances. Both protocols × all three unmask executors,
//!   with and without phase deadlines armed.
//! * **Reorder tolerance**: seeded jitter permutes frame delivery
//!   within each phase; every permutation must aggregate bit-exactly
//!   (the ingest path is order-free by construction).
//! * **Deadline rejection**: a straggler whose upload misses the
//!   Collecting deadline surfaces in the Unmasking phase, where the
//!   validating ingest rejects it as phase-confused and bills it in
//!   `rejected_frames` — the round completes as if the straggler had
//!   dropped, and nothing panics.

use sparsesecagg::coordinator::{Coordinator, PhaseDeadlines};
use sparsesecagg::exec::ExecMode;
use sparsesecagg::netsim::{LinkProfile, NetSim, NetSimConfig};
use sparsesecagg::network::draw_dropouts;
use sparsesecagg::prg::ChaCha20Rng;
use sparsesecagg::protocol::Params;

fn params(n: usize, d: usize, alpha: f64, theta: f64) -> Params {
    Params { n, d, alpha, theta, c: 1024.0 }
}

fn grads(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = ChaCha20Rng::from_seed_u64(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.next_f32() - 0.5).collect())
        .collect()
}

/// (mode, shard_size): shard_size 0 selects the monolithic path.
const EXECUTORS: &[(ExecMode, usize)] = &[
    (ExecMode::Stealing, 64),
    (ExecMode::Windowed, 64),
    (ExecMode::Monolithic, 0),
];

fn coordinator_on(secagg: bool, p: Params, entropy: u64, mode: ExecMode,
                  shard: usize, cfg: Option<NetSimConfig>) -> Coordinator {
    let mut c = match cfg {
        Some(cfg) => {
            let bus = Box::new(NetSim::over_bus(p.n, cfg));
            if secagg {
                Coordinator::new_secagg_on(p, entropy, bus)
            } else {
                Coordinator::new_sparse_on(p, entropy, bus)
            }
        }
        None if secagg => Coordinator::new_secagg(p, entropy),
        None => Coordinator::new_sparse(p, entropy),
    };
    c.exec_mode = mode;
    c.shard_size = shard;
    c.threads = 3;
    c
}

/// Two rounds (with drawn dropouts) on ideal links vs the raw bus:
/// every observable must match. `deadlines` additionally arms finite
/// per-phase budgets — on ideal links nothing is ever late, so arming
/// them must not change any result (only the virtual clock, which then
/// counts the budgets the server waited out).
fn assert_zero_impairment_exact(secagg: bool, mode: ExecMode, shard: usize,
                                deadlines: Option<PhaseDeadlines>) {
    let alpha = if secagg { 1.0 } else { 0.3 };
    let p = params(10, 600, alpha, 0.2);
    let ys = grads(p.n, p.d, 0xd1ff);
    let betas = vec![1.0 / p.n as f64; p.n];

    let mut raw = coordinator_on(secagg, p, 42, mode, shard, None);
    let mut sim = coordinator_on(secagg, p, 42, mode, shard,
                                 Some(NetSimConfig::ideal(0x1dea)));
    sim.deadlines = deadlines;
    let armed = sim.deadlines.is_some();

    for round in 0..2u32 {
        let dropped = draw_dropouts(p.n, p.theta, round, 0xd0, true);
        let (want, lw) = raw.run_round(round, &ys, &betas, &dropped)
            .expect("raw bus round");
        let (got, lg) = sim.run_round(round, &ys, &betas, &dropped)
            .expect("ideal netsim round");
        let tag = format!("secagg={secagg} {mode:?} armed={armed} \
                           round={round}");
        assert_eq!(got, want, "{tag}: aggregate differs");
        assert_eq!(lg.up_bytes, lw.up_bytes, "{tag}: up_bytes differ");
        assert_eq!(lg.down_bytes, lw.down_bytes,
                   "{tag}: down_bytes differ");
        assert_eq!(lg.comm_time_s.to_bits(), lw.comm_time_s.to_bits(),
                   "{tag}: simulated comm clock differs");
        assert_eq!(lg.client_tasks, lw.client_tasks,
                   "{tag}: scheduling differs");
        assert_eq!(lg.rejected_frames, 0, "{tag}: spurious rejects");
        assert_eq!(
            lg.phases.iter().map(|ph| ph.name).collect::<Vec<_>>(),
            lw.phases.iter().map(|ph| ph.name).collect::<Vec<_>>(),
            "{tag}: phase decomposition differs"
        );
    }
    if armed {
        // Finite budgets: the server waited each phase's timer out.
        assert!(sim.bus_clock_s() > 0.0,
                "armed deadlines must consume simulated time");
    } else {
        assert_eq!(sim.bus_clock_s(), 0.0,
                   "ideal links without deadlines must not advance \
                    the virtual clock");
    }
}

#[test]
fn zero_impairment_is_bit_exact_sparse_all_executors() {
    for &(mode, shard) in EXECUTORS {
        assert_zero_impairment_exact(false, mode, shard, None);
        assert_zero_impairment_exact(
            false, mode, shard, Some(PhaseDeadlines::uniform(1.0)));
    }
}

#[test]
fn zero_impairment_is_bit_exact_secagg_all_executors() {
    for &(mode, shard) in EXECUTORS {
        assert_zero_impairment_exact(true, mode, shard, None);
        assert_zero_impairment_exact(
            true, mode, shard, Some(PhaseDeadlines::uniform(1.0)));
    }
}

/// Jitter-only impairment: delivery order inside each phase is a
/// seeded permutation of submission order. Every seed must aggregate
/// bit-exactly against the raw bus — ingest keeps per-sender slots, so
/// arrival order is immaterial by construction, and this pins it.
#[test]
fn seeded_reorder_permutations_are_bit_exact() {
    let p = params(9, 500, 0.3, 0.2);
    let ys = grads(p.n, p.d, 0x5eed);
    let betas = vec![1.0 / p.n as f64; p.n];
    let jittery = LinkProfile {
        latency_s: 1e-4,
        jitter_s: 5e-3, // 50x the latency: heavy reordering
        ..LinkProfile::ideal()
    };
    let mut raw = coordinator_on(false, p, 9, ExecMode::Stealing, 64, None);
    let dropped = draw_dropouts(p.n, p.theta, 0, 0x0d, true);
    let (want, _) = raw.run_round(0, &ys, &betas, &dropped).unwrap();

    for seed in 0..5u64 {
        let mut sim = coordinator_on(
            false, p, 9, ExecMode::Stealing, 64,
            Some(NetSimConfig::uniform(0x900d + seed, jittery)));
        let (got, ledger) =
            sim.run_round(0, &ys, &betas, &dropped).unwrap();
        assert_eq!(got, want, "seed {seed}: reorder changed the sum");
        assert_eq!(ledger.rejected_frames, 0,
                   "seed {seed}: no deadline armed, nothing is late");
        assert!(sim.bus_clock_s() > 0.0,
                "seed {seed}: jittery delivery takes simulated time");
    }
}

/// A straggler past the Collecting deadline degrades to the dropout
/// path: its upload surfaces in the Unmasking phase, is rejected as
/// phase-confused by the ingest state machine (billed in
/// `rejected_frames`), nobody is *excluded* (lateness is not
/// equivocation), and the aggregate equals the reference round where
/// the straggler simply dropped.
#[test]
fn post_deadline_upload_is_rejected_and_degrades_to_dropout() {
    let p = params(10, 500, 0.3, 0.0);
    let ys = grads(p.n, p.d, 0x57a6);
    let betas = vec![1.0 / p.n as f64; p.n];
    let straggler = 7usize;

    let mut reference =
        coordinator_on(false, p, 13, ExecMode::Stealing, 64, None);
    let (want, _) = reference
        .run_round(0, &ys, &betas, &[straggler])
        .expect("reference with straggler dropped");

    let brisk = LinkProfile {
        latency_s: 1e-3,
        ..LinkProfile::ideal()
    };
    let mut cfg = NetSimConfig::uniform(0xdead1, brisk);
    cfg.overrides.push((
        straggler,
        LinkProfile {
            latency_s: 0.5, // 10x the Collecting budget below
            ..brisk
        },
    ));
    let mut sim =
        coordinator_on(false, p, 13, ExecMode::Stealing, 64, Some(cfg));
    sim.deadlines = Some(PhaseDeadlines {
        collecting_s: 0.05,
        unmasking_s: f64::INFINITY,
    });
    let (got, ledger) = sim
        .run_round(0, &ys, &betas, &[])
        .expect("round must survive a straggler");
    assert_eq!(got, want,
               "straggler must degrade to the dropout path exactly");
    assert_eq!(ledger.rejected_frames, 1,
               "exactly the one late upload is rejected");
    assert!(ledger.excluded_users.is_empty(),
            "lateness must not trigger equivocator exclusion");
    assert_eq!(ledger.retries, 0);
    assert!(sim.bus_clock_s() >= 0.05,
            "the Collecting phase ran out its full budget");
}

/// Same straggler, but *both* budgets finite and shorter than the
/// straggler's latency: the late upload stays in flight past every
/// phase and is expired at the next round boundary instead of ever
/// being ingested — two clean rounds back to back.
#[test]
fn straggler_past_every_deadline_expires_at_the_round_boundary() {
    let p = params(10, 400, 0.3, 0.0);
    let ys = grads(p.n, p.d, 0x57a7);
    let betas = vec![1.0 / p.n as f64; p.n];
    let straggler = 3usize;

    let mut reference =
        coordinator_on(false, p, 29, ExecMode::Stealing, 64, None);
    let brisk = LinkProfile { latency_s: 1e-3, ..LinkProfile::ideal() };
    let mut cfg = NetSimConfig::uniform(0xdead2, brisk);
    cfg.overrides.push((
        straggler,
        LinkProfile { latency_s: 10.0, ..brisk },
    ));
    let mut sim =
        coordinator_on(false, p, 29, ExecMode::Stealing, 64, Some(cfg));
    sim.deadlines = Some(PhaseDeadlines::uniform(0.05));

    for round in 0..2u32 {
        let (want, _) = reference
            .run_round(round, &ys, &betas, &[straggler])
            .unwrap();
        let (got, ledger) =
            sim.run_round(round, &ys, &betas, &[]).unwrap();
        assert_eq!(got, want, "round {round}");
        assert_eq!(ledger.rejected_frames, 0,
                   "round {round}: the upload never surfaced inside \
                    the round");
    }
}
